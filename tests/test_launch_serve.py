"""Smoke tests for the cluster serving launcher (``repro.launch.serve``):
the simulated path, the real-backend path, the standalone real-engine
demo, and the wall-clock streaming server all run end to end with tiny
configurations."""
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.launch import serve


def test_launch_sim_path_smoke(capsys):
    serve.main(["--arch", "llama3.2-1b", "--workers", "1",
                "--cpu-workers", "0", "--rate", "5", "--duration", "6",
                "--slo-ms", "5000", "--no-autoscale"])
    out = capsys.readouterr().out
    assert "served=" in out
    assert "workers alive at end: 1" in out


def test_launch_real_backend_smoke(capsys):
    serve.main(["--arch", "llama3.2-1b", "--backend", "real",
                "--workers", "1", "--cpu-workers", "0", "--rate", "3",
                "--duration", "6", "--slo-ms", "600000", "--no-autoscale"])
    out = capsys.readouterr().out
    assert "served=" in out
    # at least one profile was re-fit from real measurements
    assert "variants re-fit from real measurements:" in out
    n = int(out.rsplit("variants re-fit from real measurements:", 1)[1])
    assert n >= 1


def test_launch_real_backend_rejects_all_archs():
    with pytest.raises(SystemExit):
        serve.main(["--arch", "all", "--backend", "real"])


def test_launch_real_engine_demo_smoke(capsys):
    serve.main(["--real-engine", "--arch", "llama3.2-1b",
                "--real-reqs", "4", "--real-slots", "2"])
    out = capsys.readouterr().out
    assert "real engine" in out and "tok/s" in out


def test_launch_real_engine_demo_paged_smoke(capsys):
    """The paged/chunked knobs reach the standalone engine demo."""
    serve.main(["--real-engine", "--arch", "llama3.2-1b",
                "--real-reqs", "4", "--real-slots", "2",
                "--page-size", "8", "--chunk-threshold", "16"])
    out = capsys.readouterr().out
    assert "paged 16x8" in out and "tok/s" in out


def test_launch_sim_backend_rejects_paged_flags():
    """The paged/chunk knobs configure the real data plane; silently
    ignoring them on the sim backend would misread sim results as
    paged-engine behavior."""
    with pytest.raises(SystemExit, match="real"):
        serve.main(["--arch", "llama3.2-1b", "--page-size", "8"])
    with pytest.raises(SystemExit, match="real"):
        serve.main(["--arch", "llama3.2-1b", "--chunk-threshold", "16"])


def test_launch_optimistic_requires_page_size():
    """Optimistic admission over-commits the paged pool: without
    --page-size there is no pool to over-commit."""
    with pytest.raises(SystemExit, match="page-size"):
        serve.main(["--arch", "llama3.2-1b", "--real-engine",
                    "--admission", "optimistic"])


def test_launch_wall_clock_requires_real_backend():
    """--clock wall runs the control plane in real time; the sim executor
    has nothing to execute, so the combination is rejected up front
    rather than silently serving an idle wall clock."""
    with pytest.raises(SystemExit, match="--backend real"):
        serve.main(["--arch", "llama3.2-1b", "--clock", "wall"])
    with pytest.raises(SystemExit, match="--backend real"):
        serve.main(["--arch", "llama3.2-1b", "--clock", "wall",
                    "--real-engine"])


@pytest.mark.slow
def test_launch_wall_clock_sigint_drains_clean():
    """ISSUE 8 CI smoke: a live wall-clock server absorbs seeded Poisson
    traffic, streams at least one token, and a SIGINT mid-run drains
    in-flight work before a clean (exit 0) shutdown."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    p = subprocess.Popen(
        [sys.executable, "-c",
         "from repro.launch import serve; serve.main("
         "['--arch', 'llama3.2-1b', '--backend', 'real',"
         " '--clock', 'wall', '--workers', '1', '--cpu-workers', '0',"
         " '--rate', '2', '--duration', '60', '--slo-ms', '600000',"
         " '--no-autoscale'])"],
        cwd=root, env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    try:
        # let it build the engine and serve a few seconds of live traffic
        time.sleep(20.0)
        p.send_signal(signal.SIGINT)
        out, _ = p.communicate(timeout=120.0)
    except Exception:
        p.kill()
        raise
    assert p.returncode == 0, out
    assert "SIGINT: draining in-flight work" in out, out
    assert "clean shutdown: drained in-flight work" in out, out
    tokens = int(out.split("streamed: ", 1)[1].split(" tokens", 1)[0])
    assert tokens >= 1, out


def test_launch_real_engine_demo_optimistic_smoke(capsys):
    """The admission/preempt-policy knobs reach the standalone engine
    demo: a starved pool forces preemptions and the stream completes."""
    serve.main(["--real-engine", "--arch", "llama3.2-1b",
                "--real-reqs", "8", "--real-slots", "4",
                "--page-size", "8", "--n-pages", "12",
                "--admission", "optimistic", "--preempt-policy", "slack"])
    out = capsys.readouterr().out
    assert "paged 12x8" in out and "preemptions" in out
