"""Smoke tests for the cluster serving launcher (``repro.launch.serve``):
the simulated path, the real-backend path, and the standalone real-engine
demo all run end to end with tiny configurations."""
import pytest

from repro.launch import serve


def test_launch_sim_path_smoke(capsys):
    serve.main(["--arch", "llama3.2-1b", "--workers", "1",
                "--cpu-workers", "0", "--rate", "5", "--duration", "6",
                "--slo-ms", "5000", "--no-autoscale"])
    out = capsys.readouterr().out
    assert "served=" in out
    assert "workers alive at end: 1" in out


def test_launch_real_backend_smoke(capsys):
    serve.main(["--arch", "llama3.2-1b", "--backend", "real",
                "--workers", "1", "--cpu-workers", "0", "--rate", "3",
                "--duration", "6", "--slo-ms", "600000", "--no-autoscale"])
    out = capsys.readouterr().out
    assert "served=" in out
    # at least one profile was re-fit from real measurements
    assert "variants re-fit from real measurements:" in out
    n = int(out.rsplit("variants re-fit from real measurements:", 1)[1])
    assert n >= 1


def test_launch_real_backend_rejects_all_archs():
    with pytest.raises(SystemExit):
        serve.main(["--arch", "all", "--backend", "real"])


def test_launch_real_engine_demo_smoke(capsys):
    serve.main(["--real-engine", "--arch", "llama3.2-1b",
                "--real-reqs", "4", "--real-slots", "2"])
    out = capsys.readouterr().out
    assert "real engine" in out and "tok/s" in out


def test_launch_real_engine_demo_paged_smoke(capsys):
    """The paged/chunked knobs reach the standalone engine demo."""
    serve.main(["--real-engine", "--arch", "llama3.2-1b",
                "--real-reqs", "4", "--real-slots", "2",
                "--page-size", "8", "--chunk-threshold", "16"])
    out = capsys.readouterr().out
    assert "paged 16x8" in out and "tok/s" in out


def test_launch_sim_backend_rejects_paged_flags():
    """The paged/chunk knobs configure the real data plane; silently
    ignoring them on the sim backend would misread sim results as
    paged-engine behavior."""
    with pytest.raises(SystemExit, match="real"):
        serve.main(["--arch", "llama3.2-1b", "--page-size", "8"])
    with pytest.raises(SystemExit, match="real"):
        serve.main(["--arch", "llama3.2-1b", "--chunk-threshold", "16"])


def test_launch_optimistic_requires_page_size():
    """Optimistic admission over-commits the paged pool: without
    --page-size there is no pool to over-commit."""
    with pytest.raises(SystemExit, match="page-size"):
        serve.main(["--arch", "llama3.2-1b", "--real-engine",
                    "--admission", "optimistic"])


def test_launch_real_engine_demo_optimistic_smoke(capsys):
    """The admission/preempt-policy knobs reach the standalone engine
    demo: a starved pool forces preemptions and the stream completes."""
    serve.main(["--real-engine", "--arch", "llama3.2-1b",
                "--real-reqs", "8", "--real-slots", "4",
                "--page-size", "8", "--n-pages", "12",
                "--admission", "optimistic", "--preempt-policy", "slack"])
    out = capsys.readouterr().out
    assert "paged 12x8" in out and "preemptions" in out
