"""The QuerySpec/QueryHandle surface (ISSUE 3 tentpole).

Covers: spec validation (tagged target, mode, payload/n_inputs
consistency), handle semantics (result() pumps the event loop, callback
ordering, per-stage breakdown, SLO verdict), shim equivalence (old kwargs
forms == spec submissions for all three granularities), the spec-derived
hedge duplicate, and the offline scheduled-retry path.
"""
import dataclasses

import pytest

from repro.configs.registry import ARCHS
from repro.core.api import (ArchTarget, QueryPayload, QuerySpec,
                            UseCaseTarget, VariantTarget)
from repro.core.master import MasterConfig
from repro.sim.cluster import make_cluster

LLAMA = ARCHS["llama3.2-1b"]


def _done(q):
    return q.finish >= 0 and not q.failed


# ----------------------------------------------------------------------
# QuerySpec validation
def test_spec_constructors_tag_exactly_one_target():
    assert QuerySpec.variant("v").granularity == "variant"
    assert QuerySpec.arch("a", latency_ms=100).granularity == "arch"
    s = QuerySpec.usecase("t", "d", min_accuracy=0.5, latency_ms=100)
    assert s.granularity == "usecase"
    assert s.slo == pytest.approx(0.1)
    assert isinstance(s.target, UseCaseTarget)


def test_spec_rejects_untyped_target():
    with pytest.raises(TypeError):
        QuerySpec(target="llama3.2-1b")          # a bare string is ambiguous
    with pytest.raises(TypeError):
        QuerySpec(target=None)


def test_spec_rejects_bad_mode_and_offline_slo():
    with pytest.raises(ValueError):
        QuerySpec(ArchTarget("a"), mode="batch")
    with pytest.raises(ValueError):
        QuerySpec.arch("a", latency_ms=100, mode="offline")
    # offline without an SLO is fine (paper: no offline latency option)
    QuerySpec.arch("a", mode="offline", n_inputs=10)


def test_spec_slo_units_are_exclusive():
    with pytest.raises(ValueError):
        QuerySpec.arch("a", slo=0.1, latency_ms=100)
    assert QuerySpec.arch("a", slo=0.1).slo == QuerySpec.arch(
        "a", latency_ms=100).slo


def test_payload_n_inputs_consistency():
    p = QueryPayload.of([[1, 2, 3], [4, 5]], max_new_tokens=2)
    assert len(p) == 2
    s = QuerySpec.arch("a", payload=p)           # n_inputs derived
    assert s.n_inputs == 2
    with pytest.raises(ValueError):
        QuerySpec.arch("a", payload=p, n_inputs=3)
    with pytest.raises(ValueError):
        QueryPayload.of([])
    with pytest.raises(ValueError):
        QueryPayload.of([[]])
    with pytest.raises(ValueError):
        QueryPayload.of([[1]], max_new_tokens=0)
    with pytest.raises(ValueError):
        QuerySpec.arch("a", n_inputs=0)


def test_spec_is_immutable_and_hashable():
    s = QuerySpec.usecase("t", "d", payload=QueryPayload.of([[1, 2]]))
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.mode = "offline"
    assert hash(s) == hash(QuerySpec.usecase(
        "t", "d", payload=QueryPayload.of([[1, 2]])))


# ----------------------------------------------------------------------
# QueryHandle semantics
def test_result_pumps_the_event_loop():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=5000))
    assert not h.done
    res = h.result(timeout=60.0)                 # no run_until by the test
    assert h.done and res.ok and not res.failed
    assert c.loop.now() > 0.0                    # the loop really advanced
    assert res.latency == pytest.approx(h.query.latency)
    # breakdown partitions the latency exactly
    assert res.queue + res.load + res.compute == pytest.approx(res.latency)
    assert res.load > 0.0                        # cold query paid the load
    assert res.compute > 0.0
    assert res.slo_met is True


def test_result_timeout_raises_and_preserves_deadline():
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=5000))
    with pytest.raises(TimeoutError):
        h.result(timeout=0.3)                    # retries outlive this
    assert c.loop.now() <= 0.3 + 1e-9            # did not overshoot


def test_slo_verdict_violated():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    # impossible SLO: even the fastest variant's load alone exceeds it
    h = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=0.001))
    res = h.result(timeout=120.0)
    assert res.ok and res.slo_met is False
    # no-SLO query has no verdict
    h2 = c.api.submit(QuerySpec.variant(res.variant))
    assert h2.result(timeout=60.0).slo_met is None


def test_done_callbacks_fire_in_order_and_immediately_after():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=5000))
    order = []
    h.add_done_callback(lambda hh: order.append("first"))
    h.add_done_callback(lambda hh: order.append("second"))
    h.result(timeout=60.0)
    assert order == ["first", "second"]
    h.add_done_callback(lambda hh: order.append("late"))
    assert order == ["first", "second", "late"]  # already done -> immediate


def test_failed_query_resolves_handle():
    cfg = MasterConfig(max_retries=1, retry_delay=0.05)
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False,
                     cfg=cfg)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=5000))
    res = h.result(timeout=30.0)
    assert res.failed and not res.ok


# ----------------------------------------------------------------------
# shim equivalence: old kwargs forms == spec submissions
def _drive(c, use_spec: bool):
    vname = next(v.name for v in c.store.registry.variants.values()
                 if v.hardware == "tpu-v5e-1")
    if use_spec:
        qs = [
            c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=5000)).query,
            c.api.submit(QuerySpec.usecase(
                "text-generation", "openwebtext", min_accuracy=0.5,
                latency_ms=5000)).query,
            c.api.submit(QuerySpec.variant(vname, latency_ms=5000)).query,
        ]
    else:
        qs = [
            c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000),
            c.api.online_query(task="text-generation",
                               dataset="openwebtext", accuracy=0.5,
                               latency_ms=5000),
            c.api.online_query(mod_var=vname, latency_ms=5000),
        ]
    c.run_until(120.0)
    return qs


def test_shims_match_specs_for_all_granularities():
    results = {}
    for use_spec in (False, True):
        c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
        qs = _drive(c, use_spec)
        assert all(_done(q) for q in qs)
        results[use_spec] = (
            [q.variant for q in qs],
            [q.latency for q in qs],
            [m for m, _, _ in c.master.decision_log],
        )
    # identical selections, latencies, and decision modes
    assert results[False][0] == results[True][0]
    assert results[False][1] == pytest.approx(results[True][1])
    assert results[False][2] == results[True][2] \
        == ["modarch", "usecase", "modvar"]


def test_shim_offline_matches_spec_offline():
    done_counts = {}
    for use_spec in (False, True):
        c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
        if use_spec:
            job = c.api.submit(QuerySpec.arch(LLAMA.name, mode="offline",
                                              n_inputs=64)).job
        else:
            job = c.api.offline_query(mod_arch=LLAMA.name, n_inputs=64)
        c.run_until(120.0)
        done_counts[use_spec] = job.processed
        assert job.processed > 0
    assert done_counts[False] == done_counts[True]


def test_shim_done_cb_receives_query_and_job():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    seen = []
    q = c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000,
                           done_cb=lambda qq: seen.append(qq))
    j = c.api.offline_query(mod_arch=LLAMA.name, n_inputs=8,
                            done_cb=lambda jj: seen.append(jj))
    c.run_until(120.0)
    assert q in seen and j in seen


# ----------------------------------------------------------------------
# hedging: the duplicate is derived from the original spec (satellite)
def test_hedge_duplicate_preserves_spec_fields():
    cfg = MasterConfig(hedge_enabled=True, hedge_factor=2.0)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg)
    c.master.add_worker("accel", name="straggler", slowdown=25.0)
    v = [x for x in c.store.registry.variants.values()
         if x.hardware == "tpu-v5e-1" and x.batch_opt == 8
         and "bf16" in x.framework][0]
    for w in c.master.workers.values():
        w.load_variant(v)
    # stay inside the T_accel scale-down hysteresis so both instances are
    # still resident when the hedge looks for a backup
    c.run_until(10.0)
    # a use-case query from a named tenant, routed to the straggler
    spec = QuerySpec.usecase("text-generation", "openwebtext",
                             min_accuracy=0.5, slo=30.0, user="tenantX")
    q = c.master._query_from_spec(spec, arrival=c.loop.now())
    straggler = c.master.workers["straggler"]
    sel = type("S", (), {"variant": v, "worker": "straggler",
                         "needs_load": False})()
    straggler.enqueue(q, v.name)
    c.master._arm_hedge(q, sel)
    c.run_until(300.0)
    assert _done(q)
    dups = [m for m in c.master.metrics if m.hedge_of == q.qid]
    assert dups, "hedge never fired"
    d = dups[0]
    # pre-fix, the duplicate dropped everything but arch/slo
    assert d.task == "text-generation" and d.dataset == "openwebtext"
    assert d.min_accuracy == pytest.approx(0.5)
    assert d.user == "tenantX"
    assert d.spec is q.spec
    assert d.n_inputs == q.n_inputs and d.slo == q.slo
    # the duplicate actually served on the selected variant
    assert _done(d) and d.variant == v.name


def test_hedged_usecase_query_via_submit_path():
    """End-to-end: hedging armed by the normal submit path on a use-case
    spec keeps the duplicate faithful."""
    cfg = MasterConfig(hedge_enabled=True, hedge_factor=2.0)
    c = make_cluster(n_accel=2, archs=[LLAMA], autoscale=False, cfg=cfg)
    v = [x for x in c.store.registry.variants.values()
         if x.hardware == "tpu-v5e-1" and x.batch_opt == 8
         and "bf16" in x.framework][0]
    for w in c.master.workers.values():
        w.load_variant(v)
    c.run_until(10.0)
    h = c.api.submit(QuerySpec.usecase(
        "text-generation", "openwebtext", min_accuracy=0.5, slo=30.0,
        user="tenantY"))
    c.run_until(300.0)
    assert h.done
    for d in (m for m in c.master.metrics if m.hedge_of is not None):
        assert d.task and d.user != "public"


# ----------------------------------------------------------------------
# offline scheduled-retry path (satellite): no more inert jobs
def test_offline_query_retries_until_capacity_appears():
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, mode="offline",
                                    n_inputs=32))
    job = h.job
    # capacity appears only after the job has started retrying
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    res = h.result(timeout=600.0)
    assert res.ok and not job.failed
    assert job.processed >= job.total_inputs
    assert job.variant


def test_offline_query_shim_retries_too():
    """Regression: the kwargs shim used to return an inert OfflineJob when
    nothing could serve it yet."""
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    job = c.api.offline_query(mod_arch=LLAMA.name, n_inputs=16)
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    c.run_until(600.0)
    assert job.done and job.processed >= 16


def test_offline_query_fails_after_max_retries():
    cfg = MasterConfig(max_retries=2, retry_delay=0.05)
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False,
                     cfg=cfg)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, mode="offline",
                                    n_inputs=8))
    res = h.result(timeout=60.0)
    assert res.failed and h.job.failed
    assert h.job not in c.master.offline_done


# ----------------------------------------------------------------------
# spec replay on redispatch (tagged target, not sentinel fields)
def test_usecase_spec_redispatch_reselects():
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    h = c.api.submit(QuerySpec.usecase(
        "text-generation", "openwebtext", min_accuracy=0.5,
        latency_ms=600_000))
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    res = h.result(timeout=600.0)
    assert res.ok and res.variant
    assert isinstance(h.spec.target, UseCaseTarget)


def test_variant_spec_redispatch_pins_variant():
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    vname = next(v.name for v in c.store.registry.variants.values()
                 if v.hardware == "tpu-v5e-1")
    h = c.api.submit(QuerySpec.variant(vname, latency_ms=600_000))
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    res = h.result(timeout=600.0)
    assert res.ok and res.variant == vname
    assert isinstance(h.spec.target, VariantTarget)


def test_result_is_snapshotted_at_completion():
    """A losing hedge copy finishing later mutates the raw Query; the
    handle must keep reporting the values it completed with."""
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=5000))
    res = h.result(timeout=60.0)
    finish0, lat0 = h.query.finish, res.latency
    h.query.finish = finish0 + 100.0     # straggler overwrites the Query
    h.query.violated = True
    again = h.result(timeout=1.0)
    assert again.latency == pytest.approx(lat0)
    assert again.slo_met is True


def test_failed_hedge_duplicate_does_not_complete_original():
    """A hedge duplicate that dies on enqueue (instance gone between the
    store lookup and the worker) must not resolve the original's handle
    with bogus negative-latency state."""
    cfg = MasterConfig(hedge_enabled=True, hedge_factor=2.0)
    c = make_cluster(n_accel=2, archs=[LLAMA], autoscale=False, cfg=cfg)
    v = [x for x in c.store.registry.variants.values()
         if x.hardware == "tpu-v5e-1" and x.batch_opt == 8
         and "bf16" in x.framework][0]
    workers = list(c.master.workers.values())
    for w in workers:
        w.load_variant(v)
    c.run_until(10.0)
    spec = QuerySpec.usecase("text-generation", "openwebtext",
                             min_accuracy=0.5, slo=30.0)
    q = c.master._query_from_spec(spec, arrival=c.loop.now())
    h_done = []
    q.done_cb = lambda qq: h_done.append(qq.finish)
    sel = type("S", (), {"variant": v, "worker": workers[0].name,
                         "needs_load": False})()
    workers[0].enqueue(q, v.name)
    c.master._arm_hedge(q, sel)
    # the backup's local instance vanishes while the store still lists it
    # running: the duplicate's enqueue will fail immediately
    workers[1].instances.pop(v.name)
    c.run_until(120.0)
    assert _done(q)
    assert q.finish >= 0 and q.latency > 0       # not the dup's -1 finish
    assert h_done and h_done[0] >= 0


def test_offline_load_failure_reenters_retry_loop():
    """If the chosen worker cannot load the variant (stale memory
    accounting), the job must keep retrying — not park forever on a
    worker that will never host it."""
    cfg = MasterConfig(max_retries=3, retry_delay=0.1)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg)
    w = next(iter(c.master.workers.values()))
    w.load_variant = lambda *a, **k: False       # device "full" forever
    h = c.api.submit(QuerySpec.arch(LLAMA.name, mode="offline",
                                    n_inputs=8))
    res = h.result(timeout=60.0)                 # resolves: fails cleanly
    assert res.failed and h.job.failed
    assert h.job not in w.offline_jobs
