"""Open-loop engine core: submit()/step()/drain_completions().

Pins the refactor's two guarantees (ISSUE 2 acceptance):

* equivalence — for a fixed request set, driving the engine open-loop
  (submit all, step until idle) produces token-for-token the same outputs
  and the same trace/dispatch counts as the closed ``serve()`` loop, for a
  dense, an ssm, and a hybrid family; and
* mid-stream admission — a request submitted between decode segments is
  admitted into a free slot and completes without restarting in-flight
  slots (each request prefills exactly once).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models import build_model

# every test here builds and decodes real JAX models (fast CI deselects
# slow; the full tier-1 run still covers them)
pytestmark = pytest.mark.slow
from repro.serving.engine import Request, ServingEngine


def _build(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _serial_greedy(model, params, prompt, max_new):
    toks = list(map(int, prompt))
    for _ in range(max_new):
        logits = model.forward(params,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def _mixed_stream(cfg, n=6, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(3, 12))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 6)))
            for i in range(n)]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
def test_open_loop_matches_serve(arch):
    """submit()+step() loop == serve(): same tokens, same trace and
    dispatch counts, across dense + ssm + hybrid families."""
    cfg, model, params = _build(arch)
    kw = dict(max_batch=3, max_len=64, decode_block=4, min_bucket=4)
    closed = ServingEngine(model, params, **kw)
    closed_reqs = _mixed_stream(cfg)
    closed.serve(closed_reqs)
    closed_by_rid = {r.rid: r for r in closed_reqs}

    opened = ServingEngine(model, params, **kw)
    reqs = _mixed_stream(cfg)
    for r in reqs:
        opened.submit(r)
    steps = 0
    while opened.busy:
        steps += opened.step()
    done = opened.drain_completions()

    assert sorted(r.rid for r in done) == [r.rid for r in reqs]
    for r in reqs:
        np.testing.assert_array_equal(
            np.asarray(r.tokens), np.asarray(closed_by_rid[r.rid].tokens),
            err_msg=f"{arch}: rid={r.rid}")
    for key in ("prefill_traces", "decode_traces"):
        assert opened.stats[key] == closed.stats[key], \
            (key, opened.stats, closed.stats)
    assert steps == opened.stats["decode_steps"]


def test_open_loop_dispatch_counts_match_serve():
    """First pass through each engine: identical dispatch counts too."""
    cfg, model, params = _build("llama3.2-1b")
    kw = dict(max_batch=3, max_len=64, decode_block=4, min_bucket=4)
    closed = ServingEngine(model, params, **kw)
    closed.serve(_mixed_stream(cfg))
    opened = ServingEngine(model, params, **kw)
    for r in _mixed_stream(cfg):
        opened.submit(r)
    while opened.busy:
        opened.step()
    assert opened.stats == closed.stats


def test_mid_stream_admission():
    """A request submitted between segments joins the next step() and the
    in-flight request keeps decoding in its slot (no re-prefill)."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        decode_block=2, min_bucket=4)
    r1 = Request(rid=1, prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=7)
    eng.submit(r1)
    n = eng.step()
    assert 0 < n <= 2
    assert eng.busy and r1.tokens is None        # r1 is mid-decode
    # arrives between segments, into the free slot
    r2 = Request(rid=2, prompt=np.arange(6, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=3)
    eng.submit(r2)
    while eng.busy:
        eng.step()
    done = eng.drain_completions()
    assert sorted(r.rid for r in done) == [1, 2]
    # each request prefilled exactly once: the in-flight slot was never
    # restarted by the mid-stream admission
    assert eng.stats["prefill_dispatches"] == 2, eng.stats
    assert eng.stats["admitted"] == 2, eng.stats
    for r in (r1, r2):
        want = _serial_greedy(model, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      np.asarray(want, np.int32),
                                      err_msg=f"rid={r.rid}")
    assert r1.latency >= r2.latency >= 0.0       # both clocked from arrival


def test_serve_interleaved_with_open_loop_submits():
    """serve() on an engine with an open-loop request in flight must not
    swallow that request's completion record."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        decode_block=2, min_bucket=4)
    r0 = Request(rid=0, prompt=np.arange(4, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=2)
    eng.submit(r0)                      # open-loop caller, not yet stepped
    r1 = Request(rid=1, prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=2)
    eng.serve([r1])
    assert r1.tokens is not None
    # r0 was co-served but its completion stays for its own driver
    while eng.busy:
        eng.step()
    assert [r.rid for r in eng.drain_completions()] == [0]
    assert r0.tokens is not None


def test_step_on_idle_engine_is_a_noop():
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        decode_block=2, min_bucket=4)
    assert not eng.busy
    assert eng.step() == 0
    assert eng.stats["decode_dispatches"] == 0
    assert eng.drain_completions() == []
