"""Streaming semantics (ISSUE 8): partial outputs at decode-segment
granularity must be a pure *view* of the exact same generation —

* chunks are delivered in emission order,
* concatenating a request's chunks is bit-identical to its final tokens
  (and to a non-streaming engine's output), across dense / ssm / hybrid
  families on both KV layouts (contiguous + paged),
* time-to-first-token is monotone: arrival <= first_token <= completion,
* a preempted-then-replayed request never re-streams tokens it already
  delivered (the ``Request.streamed`` cursor survives parking).
"""
import numpy as np
import pytest

import jax

from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine

# every test here builds real JAX models
pytestmark = pytest.mark.slow

_BUILT = {}


def _build(arch):
    if arch not in _BUILT:
        cfg = ARCHS[arch].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _BUILT[arch] = (cfg, model, params)
    return _BUILT[arch]


def _stream(cfg, n=6, seed=7, max_new=(4, 10)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(3, 10))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def _run_streaming(eng, reqs):
    """Drive to drain, returning chunks as (rid, tokens, t) in the order
    the engine emitted them."""
    for r in reqs:
        eng.submit(r)
    chunks = []
    while eng.busy:
        eng.step()
        for r, toks, t in eng.drain_partial_outputs():
            chunks.append((r.rid, list(toks), t))
    eng.drain_completions()
    assert eng.drain_partial_outputs() == []
    return chunks


def _concat(chunks, rid):
    return [t for r, toks, _ in chunks if r == rid for t in toks]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
@pytest.mark.parametrize("page_size", [None, 8])
def test_stream_concat_bit_identical(arch, page_size):
    """Streamed chunks concatenate to exactly the final tokens, and
    enabling streaming does not perturb generation at all."""
    cfg, model, params = _build(arch)
    kw = dict(max_batch=3, max_len=64, decode_block=4, min_bucket=4)
    if page_size is not None:
        kw["page_size"] = page_size
    ref_engine = ServingEngine(model, params, **kw)
    ref = _stream(cfg)
    ref_engine.serve(ref)

    eng = ServingEngine(model, params, stream=True, **kw)
    got = _stream(cfg)
    chunks = _run_streaming(eng, got)
    assert chunks, "streaming engine emitted no partial outputs"
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"stream=True changed rid={a.rid}")
        assert _concat(chunks, b.rid) == [int(x) for x in b.tokens], \
            f"chunk concat != final tokens for rid={b.rid}"


def test_stream_emission_order_and_ttft_monotone():
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, stream=True, max_batch=3,
                        max_len=64, decode_block=4, min_bucket=4)
    got = _stream(cfg)
    chunks = _run_streaming(eng, got)
    ts = [t for _, _, t in chunks]
    assert ts == sorted(ts), "chunks not in emission order"
    for r in got:
        mine = [(toks, t) for rid, toks, t in chunks if rid == r.rid]
        assert mine, f"rid={r.rid} streamed nothing"
        assert r.first_token == mine[0][1], \
            "first_token must stamp the first chunk's harvest time"
        assert r.arrival <= r.first_token
        # completion wall time = arrival + latency
        assert r.first_token <= r.arrival + r.latency + 1e-6
        # max_new >= 4 with decode_block=4 < max_new for some requests:
        # at least the multi-segment requests see TTFT strictly before
        # completion (checked in aggregate below)
    multi = [r for r in got if r.max_new_tokens > 4]
    assert any(r.first_token < r.arrival + r.latency for r in multi)


@pytest.mark.parametrize("page_size", [None, 8])
def test_preempt_replay_never_restreams(page_size):
    """Preempt a slot after it has streamed at least one chunk; the
    replayed request must deliver only the tokens beyond its cursor —
    concat stays bit-identical with zero duplicates."""
    cfg, model, params = _build("llama3.2-1b")
    kw = dict(max_batch=2, max_len=64, decode_block=4, min_bucket=4)
    if page_size is not None:
        kw["page_size"] = page_size
    eng = ServingEngine(model, params, stream=True, **kw)
    got = _stream(cfg, max_new=(8, 12))
    for r in got:
        eng.submit(r)
    chunks = []
    victim = None
    while eng.busy:
        eng.step()
        for r, toks, t in eng.drain_partial_outputs():
            chunks.append((r.rid, list(toks), t))
        if victim is None:
            live = [s for s in range(eng.max_batch)
                    if eng._slot_req[s] is not None
                    and eng._slot_req[s].streamed > 0
                    and eng._slot_req[s].streamed
                    < eng._slot_req[s].max_new_tokens]
            if live:
                victim = eng._slot_req[live[0]]
                eng.preempt(live[0])
    eng.drain_completions()
    assert victim is not None, "no slot had streamed before preemption"
    assert victim.preemptions >= 1
    for r in got:
        cat = _concat(chunks, r.rid)
        assert cat == [int(x) for x in r.tokens], \
            f"rid={r.rid} re-streamed or dropped tokens across preemption"
        assert len(cat) == len(r.tokens)   # no duplicates slipped in


def test_streaming_through_control_plane_virtual_clock():
    """End to end under the deterministic EventLoop: an executor with
    ``stream=True`` pushes chunks through worker -> Query.on_tokens ->
    QueryHandle; callbacks arrive in order, replay to late subscribers,
    concat matches ``result().outputs``, and ``ttft`` <= latency."""
    from repro.core.api import QueryPayload, QuerySpec
    from repro.serving.executor import EngineExecutorConfig
    from repro.sim.cluster import make_cluster

    arch = ARCHS["llama3.2-1b"]
    ecfg = EngineExecutorConfig(max_batch=4, max_len=48, decode_block=4,
                                stream=True)
    c = make_cluster(n_accel=1, archs=[arch], autoscale=False,
                     backend="real", engine_cfg=ecfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, arch.reduced().vocab, size=6),
               rng.integers(0, arch.reduced().vocab, size=9)]
    h = c.api.submit(QuerySpec.arch(
        arch.name, latency_ms=600_000,
        payload=QueryPayload.of(prompts, max_new_tokens=10)))
    live = []
    h.on_tokens(live.append)
    res = h.result(timeout=600.0)
    assert res.ok and res.outputs is not None
    assert live, "no streamed chunks reached the handle"
    ts = [c.t for c in live]
    assert ts == sorted(ts)
    for idx, out in enumerate(res.outputs):
        cat = [t for c in live if c.input_idx == idx for t in c.tokens]
        assert cat == [int(x) for x in out]
    # a late subscriber replays the full history in the same order
    replay = []
    h.on_tokens(replay.append)
    assert replay == live
    assert h.chunks and len(h.chunks) == len(live)
    assert h.ttft is not None and 0.0 <= h.ttft <= res.latency + 1e-9
