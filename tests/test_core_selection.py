"""Unit tests: model-less abstraction, profiler, Algorithm-1 selection,
decision cache, metadata snapshot/restore."""
import jax  # noqa: F401  (ensures jax initializes once for the session)
import pytest

from repro.configs.registry import ARCHS
from repro.core import profiler as prof
from repro.core.abstraction import Registry
from repro.core.metadata import InstanceState, MetadataStore
from repro.core.selection import VariantSelector
from repro.sim import hardware as HW


@pytest.fixture()
def store():
    s = MetadataStore()
    prof.register_all(s.registry, [ARCHS["llama3.2-1b"], ARCHS["yi-9b"],
                                   ARCHS["whisper-base"]])
    # one live accel worker, one cpu worker
    s.upsert_worker("w0", ("cpu-host", "tpu-v5e-1"), 0.0)
    s.heartbeat("w0", {"cpu-host": 0.1, "tpu-v5e-1": 0.2},
                {"cpu-host": 0.0, "tpu-v5e-1": 0.0}, 0.0)
    s.upsert_worker("w1", ("cpu-host",), 0.0)
    s.heartbeat("w1", {"cpu-host": 0.05}, {"cpu-host": 0.0}, 0.0)
    return s


def test_variant_generation_counts():
    reg = Registry()
    n = prof.register_all(reg, list(ARCHS.values()))
    assert n >= 80, f"variant zoo too small: {n}"
    # every variant fits its platform
    for v in reg.variants.values():
        assert v.profile.peak_memory <= HW.HARDWARE[v.hardware].mem_capacity
    # the giants have no host-feasible cpu f32 variant
    big = [v for v in reg.variants.values()
           if v.arch == "qwen3-moe-235b-a22b" and v.hardware == "cpu-host"]
    assert not big


def test_linear_fit_matches_roofline():
    cfg = ARCHS["llama3.2-1b"]
    hw = HW.HARDWARE["tpu-v5e-1"]
    p = prof.analytic_profile(cfg, hw, "bf16", 8)
    wl = prof.workload_model(cfg)
    for b in (1, 4, 8):
        t_roof = HW.roofline_latency(
            wl.flops(b), wl.bytes_moved(b, wl.n_total * 2.0), hw, 0.6)
        assert p.latency(b) == pytest.approx(t_roof, rel=0.35), b


def test_int8_variant_faster_at_small_batch():
    cfg = ARCHS["llama3.2-1b"]
    hw = HW.HARDWARE["tpu-v5e-1"]
    p8 = prof.analytic_profile(cfg, hw, "int8", 1)
    p16 = prof.analytic_profile(cfg, hw, "bf16", 1)
    assert p8.latency(1) < p16.latency(1)


def test_selection_outcome3_load(store):
    sel = VariantSelector(store)
    r = sel.select_arch("llama3.2-1b", 1, 0.05)
    assert r.outcome == "load" and r.variant is not None
    assert r.worker in ("w0", "w1")
    # the chosen variant minimizes load+inference among valid ones
    v = r.variant
    for w in store.registry.variants_of("llama3.2-1b"):
        if w.profile.max_batch >= 1 and w.profile.latency(1) <= 0.05 \
                and sel._worker_for_load(w) is not None:
            assert (v.profile.load_latency + v.profile.latency(1)) <= \
                (w.profile.load_latency + w.profile.latency(1)) + 1e-9


def test_selection_prefers_running_then_caches(store):
    sel = VariantSelector(store)
    # mark one valid variant as running on w0
    cands = [v for v in store.registry.variants_of("llama3.2-1b")
             if v.hardware == "tpu-v5e-1"]
    v = cands[0]
    store.set_instance(InstanceState(variant=v.name, worker="w0",
                                     running=True))
    r1 = sel.select_arch("llama3.2-1b", 1, 1.0)
    assert r1.outcome == "running" and r1.variant.name == v.name
    r2 = sel.select_arch("llama3.2-1b", 1, 1.0)
    assert r2.outcome == "cache" and r2.variant.name == v.name
    # overload the instance -> cache must not return it
    inst = store.instance(v.name, "w0")
    inst.qps = 1e9
    r3 = sel.select_arch("llama3.2-1b", 1, 1.0)
    assert r3.outcome != "cache" or r3.variant.name != v.name


def test_usecase_selection_respects_accuracy(store):
    sel = VariantSelector(store)
    r = sel.select_usecase("text-generation", "openwebtext",
                           accuracy=0.71, batch=1, latency_slo=None)
    assert r.variant is not None
    assert r.variant.arch == "yi-9b"    # only arch above 0.71 registered here
    r2 = sel.select_usecase("asr", "librispeech", 0.0, 1, None)
    assert r2.variant.arch == "whisper-base"
    r3 = sel.select_usecase("text-generation", "openwebtext",
                            accuracy=0.99, batch=1, latency_slo=None)
    assert r3.outcome == "reject"


def test_variant_validity_batch_and_slo(store):
    sel = VariantSelector(store)
    r = sel.select_arch("llama3.2-1b", 64, None)
    assert r.variant.profile.max_batch >= 64


def test_snapshot_restore_roundtrip(store):
    blob = store.snapshot()
    restored = MetadataStore.restore(blob)
    assert set(restored.registry.archs) == set(store.registry.archs)
    assert set(restored.registry.variants) == set(store.registry.variants)
    v0 = next(iter(store.registry.variants.values()))
    v1 = restored.registry.variants[v0.name]
    assert v1.profile.m == pytest.approx(v0.profile.m)
    # dynamic state intentionally NOT in the snapshot
    assert not restored.workers


def test_private_model_access(store):
    from repro.core.abstraction import ModelArchInfo
    store.registry.add_arch(ModelArchInfo(
        name="secret", task="text-generation", dataset="openwebtext",
        accuracy=0.99, submitter="alice", is_private=True,
        allowed_users=("bob",)))
    reg = store.registry
    assert reg.archs["secret"].accessible_by("alice")
    assert reg.archs["secret"].accessible_by("bob")
    assert not reg.archs["secret"].accessible_by("eve")
