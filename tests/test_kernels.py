"""Pallas kernel validation: interpret-mode sweep over shapes/dtypes against
the pure-jnp oracles in ``repro.kernels.ref``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.ops import flash_attention_grouped


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _qkv(rng, B, H, K, S, T, D, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, H, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, K, T, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, K, T, D), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # (B, H, K, S, T, D, causal)
    (1, 4, 4, 128, 128, 64, True),        # MHA causal
    (2, 8, 2, 256, 256, 64, True),        # GQA group=4
    (1, 4, 1, 128, 256, 128, False),      # MQA, rectangular, bidirectional
    (1, 2, 2, 256, 512, 64, True),        # long KV
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention(case, dtype):
    B, H, K, S, T, D, causal = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    q, k, v = _qkv(rng, B, H, K, S, T, D, dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_valid_len():
    B, H, K, S, T, D = 1, 4, 2, 128, 256, 64
    q, k, v = _qkv(jax.random.PRNGKey(0), B, H, K, S, T, D, jnp.float32)
    vlen = 200
    out = flash_attention(q, k, v, valid_len=jnp.int32(vlen), causal=True,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, kv_valid_len=vlen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_q_offset():
    """Decode-like chunk: queries at positions [offset, offset+S)."""
    B, H, K, S, T, D = 1, 2, 2, 128, 256, 64
    q, k, v = _qkv(jax.random.PRNGKey(1), B, H, K, S, T, D, jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=100, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


DECODE_CASES = [
    # (B, K, G, T, D, valid)
    (1, 4, 1, 512, 64, 512),
    (2, 2, 4, 1024, 64, 700),     # GQA + partial cache
    (1, 8, 4, 512, 128, 300),
    (4, 1, 8, 2048, 64, 2048),    # MQA long cache
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention(case, dtype):
    B, K, G, T, D, valid = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, K, G, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, K, T, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, K, T, D), jnp.float32).astype(dtype)
    out = decode_attention(q, k, v, valid_len=jnp.int32(valid),
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


MM_CASES = [
    (128, 256, 128),
    (256, 512, 384),
    (128, 1024, 256),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", MM_CASES)
def test_int8_matmul(case, dtype):
    M, Kd, N = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2 = jax.random.split(rng)
    x = jax.random.normal(k1, (M, Kd), jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (Kd, N), jnp.float32)
    w_q, scales = ref.quantize_int8(w)
    out = int8_matmul(x, w_q, scales, interpret=True)
    want = ref.int8_matmul_ref(x, w_q, scales)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-3)


def test_grouped_adapter_matches_model_layout():
    """ops.flash_attention_grouped == layers.attention_core (xla)."""
    from repro.models.layers import attention_core
    B, S, K, G, D = 2, 128, 2, 2, 64
    rng = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, S, K, G, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, K, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, K, D), jnp.float32)
    got = flash_attention_grouped(q, k, v, causal=True, interpret=True)
    want = attention_core(q, k, v, causal=True, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_adapter_matches_model_layout():
    from repro.models.layers import attention_core
    B, K, G, T, D = 2, 2, 4, 256, 64
    rng = jax.random.PRNGKey(8)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, 1, K, G, D), jnp.float32)
    k = jax.random.normal(k2, (B, T, K, D), jnp.float32)
    v = jax.random.normal(k3, (B, T, K, D), jnp.float32)
    got = flash_attention_grouped(q, k, v, causal=False, kv_valid_len=200,
                                  interpret=True)
    want = attention_core(q, k, v, causal=False, kv_valid_len=200, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


PAGED_DECODE_CASES = [
    # (B, K, G, n_pages, page_size, pages_per_slot, D)
    (2, 2, 4, 16, 64, 4, 64),
    (3, 4, 1, 8, 128, 2, 64),
    (1, 1, 8, 32, 32, 8, 128),    # MQA, fine pages
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PAGED_DECODE_CASES)
def test_paged_decode_attention(case, dtype):
    """Block-table kernel == gathering each slot's pages into a
    contiguous cache and running the dense reference, including sentinel
    block-table entries past the per-slot valid length."""
    from repro.kernels.decode_attention import paged_decode_attention

    B, K, G, n_pages, ps, P, D = case
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    q = jax.random.normal(k1, (B, K, G, D), jnp.float32).astype(dtype)
    k_pool = jax.random.normal(k2, (n_pages, ps, K, D),
                               jnp.float32).astype(dtype)
    v_pool = jax.random.normal(k3, (n_pages, ps, K, D),
                               jnp.float32).astype(dtype)
    # each slot draws distinct pages; entries past its allocation carry
    # the sentinel n_pages (clamped by the kernel, masked by valid_len)
    perm = jax.random.permutation(k4, n_pages)[: B * P].reshape(B, P)
    valid = jax.random.randint(k5, (B,), 1, P * ps + 1)
    n_alloc = -(-valid // ps)                      # pages actually held
    bt = jnp.where(jnp.arange(P)[None, :] < n_alloc[:, None], perm,
                   n_pages)
    out = paged_decode_attention(q, k_pool, v_pool, bt, valid,
                                 interpret=True)
    # reference: gather pages (clamp sentinels) -> (B, K, T, D) dense
    gathered_k = jnp.take(k_pool, jnp.clip(bt, 0, n_pages - 1), axis=0)
    gathered_v = jnp.take(v_pool, jnp.clip(bt, 0, n_pages - 1), axis=0)
    kc = gathered_k.reshape(B, P * ps, K, D).transpose(0, 2, 1, 3)
    vc = gathered_v.reshape(B, P * ps, K, D).transpose(0, 2, 1, 3)
    wants = [ref.decode_attention_ref(q[i:i + 1], kc[i:i + 1],
                                      vc[i:i + 1], valid[i])
             for i in range(B)]
    want = jnp.concatenate(wants, axis=0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


FUSED_PAGED_CASES = [
    # (B, K, G, n_logical, page_size, pages_per_slot, D)
    (2, 2, 4, 12, 64, 4, 64),
    (3, 4, 1, 8, 128, 2, 64),
    (2, 1, 8, 16, 32, 8, 128),    # MQA, fine pages
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FUSED_PAGED_CASES)
def test_fused_paged_decode_attention(case, dtype):
    """Fused write+attend kernel == XLA pool scatter followed by the
    masked paged attend, on a trash-page pool (one extra page at the
    sentinel index) with a mix of live, first-token, and sentinel slots.
    Checks the attention output, the written pool pages, and that no
    other live page is disturbed."""
    from repro.kernels.decode_attention import fused_paged_decode_attention
    from repro.models import kvcache as KV
    from repro.models.layers import paged_attention_core

    B, K, G, n_logical, ps, P, D = case
    n_phys = n_logical + 1            # + trash page == sentinel index
    sent = n_logical
    rng = jax.random.PRNGKey(hash(case) % 2**31)
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(rng, 7)
    q = jax.random.normal(k1, (B, K, G, D), jnp.float32).astype(dtype)
    k_pool = jax.random.normal(k2, (n_phys, ps, K, D),
                               jnp.float32).astype(dtype)
    v_pool = jax.random.normal(k3, (n_phys, ps, K, D),
                               jnp.float32).astype(dtype)
    k_new = jax.random.normal(k4, (B, K, D), jnp.float32).astype(dtype)
    v_new = jax.random.normal(k5, (B, K, D), jnp.float32).astype(dtype)
    # slot B-1 is inactive (all-sentinel row, its write lands in the
    # trash page); the rest hold exactly the pages their position needs
    perm = jax.random.permutation(k6, n_logical)[: B * P].reshape(B, P)
    pos = jax.random.randint(k7, (B,), 0, P * ps)
    pos = pos.at[0].set(0)                         # first-token slot
    n_alloc = pos // ps + 1
    bt = jnp.where(jnp.arange(P)[None, :] < n_alloc[:, None], perm, sent)
    bt = bt.at[B - 1].set(sent)

    out, kp2, vp2 = fused_paged_decode_attention(
        q, k_new, v_new, k_pool, v_pool, bt, pos, interpret=True)

    # reference path: scatter (sentinel rows land in the trash page on
    # this layout too), then the masked block-table attend
    kp_ref, vp_ref = KV.paged_update_layer_cache(
        k_pool, v_pool, k_new[:, None], v_new[:, None], bt, pos)
    o_ref = paged_attention_core(q[:, None], kp_ref, vp_ref, bt,
                                 kv_valid_len=pos + 1, impl="xla")[:, 0]

    live = sorted({int(p) for p in np.asarray(bt).ravel() if p < sent})
    idle = [p for p in range(n_logical) if p not in live]
    np.testing.assert_array_equal(np.asarray(kp2)[live],
                                  np.asarray(kp_ref)[live])
    np.testing.assert_array_equal(np.asarray(vp2)[live],
                                  np.asarray(vp_ref)[live])
    np.testing.assert_array_equal(np.asarray(kp2)[idle],
                                  np.asarray(k_pool)[idle])
    np.testing.assert_array_equal(np.asarray(vp2)[idle],
                                  np.asarray(v_pool)[idle])
    np.testing.assert_allclose(np.asarray(out, np.float32)[:B - 1],
                               np.asarray(o_ref, np.float32)[:B - 1],
                               **tol(dtype))
