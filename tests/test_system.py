"""End-to-end behaviour test for the full INFaaS system: register models,
serve all three query granularities under load, autoscale, survive a worker
failure, and recover the metadata store from a snapshot."""
from repro.configs.registry import ARCHS
from repro.core.metadata import MetadataStore
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals


def test_full_system_lifecycle():
    c = make_cluster(n_accel=2, n_cpu=1,
                     archs=[ARCHS["llama3.2-1b"], ARCHS["yi-9b"],
                            ARCHS["whisper-base"]], autoscale=True)

    # all three granularities of the model-less abstraction
    qs = [
        c.api.online_query(mod_arch="llama3.2-1b", latency_ms=200),
        c.api.online_query(task="text-generation", dataset="openwebtext",
                           accuracy=0.71, latency_ms=500),
        c.api.online_query(task="asr", dataset="librispeech",
                           accuracy=0.0, latency_ms=500),
    ]
    # background load + an offline job sharing the same workers
    poisson_arrivals(
        c.loop, lambda t: 30.0,
        lambda t: c.api.online_query(mod_arch="llama3.2-1b", latency_ms=200),
        t_end=40.0, seed=0)
    job = c.api.offline_query(mod_arch="yi-9b", n_inputs=100)

    c.run_until(20.0)
    # inject a worker failure mid-run
    victim = next(iter(c.master.workers))
    c.master.fail_worker(victim)
    c.run_until(120.0)

    # the three tagged queries completed on suitable variants
    assert all(q.finish >= 0 and not q.failed for q in qs)
    assert qs[1].variant.startswith("yi-9b")          # accuracy bound
    assert qs[2].variant.startswith("whisper-base")   # task routing
    # background load survived the failure (re-dispatch)
    done = [q for q in c.master.metrics if q.kind == "online"]
    ok = [q for q in done if not q.failed]
    assert len(ok) / max(len(done), 1) > 0.95, \
        f"only {len(ok)}/{len(done)} queries survived the failure"
    # offline made progress in the slack
    assert job.processed > 0
    # dead worker is fully evicted from the routing state
    assert not c.store.workers[victim].alive
    assert not c.store.worker_instances(victim)

    # metadata snapshot -> restore preserves the registry (master failover)
    blob = c.store.snapshot()
    restored = MetadataStore.restore(blob)
    assert set(restored.registry.variants) == set(c.store.registry.variants)
