"""Sharding-rule invariants: for every architecture, the partition-spec
trees must exactly mirror the parameter/cache pytree structures (this is
what makes the multi-pod dry-run's in_shardings valid), and every sharded
dim must divide the production mesh axes."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import (MeshAxes, batch_specs, cache_specs,
                                        param_specs)
from repro.models import build_model

# production meshes, described without touching jax device state
POD = MeshAxes(data=("data",), model="model", data_size=16, model_size=16)
MULTIPOD = MeshAxes(data=("pod", "data"), model="model", data_size=32,
                    model_size=16)

def IS_SPEC(x):
    return isinstance(x, P)


def _struct(tree):
    return jax.tree.structure(tree, is_leaf=IS_SPEC)


@pytest.mark.parametrize("ax", [POD, MULTIPOD], ids=["pod", "multipod"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_match_init_structure(arch, ax):
    cfg = ARCHS[arch]
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_specs(cfg, ax)
    assert jax.tree.structure(shapes) == _struct(specs), arch
    # rank match + divisibility of every sharded dim
    axis_size = {"data": 16, "pod": 2, "model": 16}
    for leaf, spec in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(specs, is_leaf=IS_SPEC)):
        assert len(spec) <= len(leaf.shape), (arch, leaf.shape, spec)
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            total = 1
            for n in names:
                total *= axis_size[n]
            assert leaf.shape[dim] % total == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_specs_match_cache_structure(arch):
    cfg = ARCHS[arch]
    model = build_model(cfg)
    shape = SHAPES["decode_32k"]
    cache = model.cache_shapes(shape.global_batch, shape.seq_len,
                               enc_len=shape.seq_len)
    specs = cache_specs(cfg, shape.global_batch, POD)
    assert jax.tree.structure(cache) == _struct(specs), arch
    axis_size = {"data": 16, "pod": 2, "model": 16}
    for leaf, spec in zip(jax.tree.leaves(cache),
                          jax.tree.leaves(specs, is_leaf=IS_SPEC)):
        for dim, names in enumerate(spec):
            if names is None:
                continue
            names = names if isinstance(names, tuple) else (names,)
            total = 1
            for n in names:
                total *= axis_size[n]
            assert leaf.shape[dim] % total == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_batch_specs_structure(arch):
    cfg = ARCHS[arch]
    specs = batch_specs(cfg, 256, MULTIPOD)
    assert "tokens" in specs and "targets" in specs
    if cfg.family == "audio":
        assert "frames" in specs
    if cfg.family == "vlm":
        assert "image_embeds" in specs
    # batch 1 (long_500k) must not be sharded over data
    s1 = batch_specs(cfg, 1, MULTIPOD)
    assert s1["tokens"][0] is None
