"""Paged KV cache + chunked prefill: the paged engine must be
output-indistinguishable from the contiguous engine while admitting by
pages instead of max-shape slots.

Pins the refactor's guarantees (ISSUE 4 acceptance):

* paged-vs-contiguous equivalence — bit-identical greedy tokens and
  pinned ``prefill_traces``/``decode_traces`` for dense + ssm + hybrid on
  a mixed-length stream that includes a prompt longer than one page;
* chunked prefill — a near-``max_len`` prompt admitted mid-decode runs
  zero extra prefill dispatches (it teacher-forces through the shared
  decode segments) and the in-flight request's decode cadence is
  unchanged;
* capacity — on a long-tail stream the paged engine admits strictly more
  concurrent requests than ``pool_positions / max_len`` max-shape slots;
* page hygiene — every page returns to the free list at drain, and
  admission is gated (FIFO) on free pages.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models import build_model

# every test here builds and decodes real JAX models (fast CI deselects
# slow; the full tier-1 run still covers them)
pytestmark = pytest.mark.slow
from repro.serving.engine import Request, ServingEngine  # noqa: E402

_BUILT = {}


def _build(arch):
    if arch not in _BUILT:
        cfg = ARCHS[arch].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _BUILT[arch] = (cfg, model, params)
    return _BUILT[arch]


def _serial_greedy(model, params, prompt, max_new):
    toks = list(map(int, prompt))
    for _ in range(max_new):
        logits = model.forward(params,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def _mixed_stream(cfg, max_len=64, seed=3, n=6):
    """Mixed lengths including one prompt spanning several 8-wide pages."""
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(3, 12))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 6)))
            for i in range(n - 1)]
    # one prompt longer than a page (and than the chunk threshold below)
    reqs.append(Request(rid=n - 1,
                        prompt=rng.integers(0, cfg.vocab, size=29)
                        .astype(np.int32),
                        max_new_tokens=4))
    return reqs


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
def test_paged_matches_contiguous_bit_identical(arch):
    """Same stream through contiguous and paged engines: identical greedy
    tokens per request and identical executable counts (the paged layout
    adds no prefill buckets and keeps the single decode program)."""
    cfg, model, params = _build(arch)
    kw = dict(max_batch=3, max_len=64, decode_block=4, min_bucket=4)
    cont = ServingEngine(model, params, **kw)
    r_cont = _mixed_stream(cfg)
    cont.serve(r_cont)

    paged = ServingEngine(model, params, page_size=8, **kw)
    r_paged = _mixed_stream(cfg)
    paged.serve(r_paged)

    for a, b in zip(r_cont, r_paged):
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"{arch}: rid={a.rid} plen={len(a.prompt)}")
    for key in ("prefill_traces", "decode_traces", "prefill_dispatches",
                "decode_dispatches", "admitted"):
        assert cont.stats[key] == paged.stats[key], \
            (key, cont.stats, paged.stats)
    if paged._paged:
        assert paged._alloc.n_free == paged.n_pages  # full drain


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-1.2b"])
def test_chunked_prefill_matches_serial_greedy(arch):
    """Chunked admission (prompt > threshold teacher-forced through the
    decode loop) still yields exact greedy outputs, with zero prefill
    dispatches for the chunked prompts."""
    cfg, model, params = _build(arch)
    eng = ServingEngine(model, params, max_batch=3, max_len=64,
                        decode_block=4, min_bucket=4, page_size=8,
                        chunk_threshold=12)
    reqs = _mixed_stream(cfg)
    n_chunked = sum(len(r.prompt) > 12 for r in reqs)
    eng.serve(reqs)
    assert eng.stats["chunk_admits"] == n_chunked > 0
    for r in reqs:
        want = _serial_greedy(model, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(
            np.asarray(r.tokens), np.asarray(want, np.int32),
            err_msg=f"{arch}: rid={r.rid} plen={len(r.prompt)}")


def test_chunked_admission_mid_decode_does_not_stall():
    """A near-max_len prompt admitted mid-stream consumes its prompt
    inside the shared decode segments: the in-flight short request sees
    ZERO extra dispatches (its tokens keep arriving one decode_block per
    step) and both outputs stay exact."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=4, min_bucket=4, page_size=8,
                        chunk_threshold=8)
    short = Request(rid=1, prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=12)
    eng.submit(short)
    eng.step()                        # first 4 of short's tokens
    long = Request(rid=2, prompt=(np.arange(55, dtype=np.int32)
                                  % cfg.vocab), max_new_tokens=4)
    eng.submit(long)                  # arrives mid-decode
    steps_for_short = 1
    while short.tokens is None:
        eng.step()
        steps_for_short += 1
    # short needed ceil(12 / 4) = 3 segments — the long admission added
    # no prefill stall in between (one chunk of its prompt rides along
    # in each of the same fused dispatches)
    assert steps_for_short == 3, steps_for_short
    assert eng.stats["prefill_dispatches"] == 1     # short only
    assert eng.stats["chunk_admits"] == 1           # long, no prefill
    while eng.busy:
        eng.step()
    assert {r.rid for r in eng.drain_completions()} == {1, 2}
    for r in (short, long):
        want = _serial_greedy(model, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      np.asarray(want, np.int32),
                                      err_msg=f"rid={r.rid}")


def test_paged_admits_beyond_max_shape_capacity():
    """With the pool sized for 2 max-shape slots, the paged engine admits
    strictly more than 2 concurrent short requests (acceptance: beats
    max_batch_contiguous = pool_positions / max_len on a long tail)."""
    cfg, model, params = _build("llama3.2-1b")
    max_len, page = 64, 8
    pool_slots = 2                       # pool = 128 positions = 16 pages
    eng = ServingEngine(model, params, max_batch=8, max_len=max_len,
                        decode_block=4, min_bucket=4, page_size=page,
                        n_pages=pool_slots * max_len // page)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=6)
                    .astype(np.int32),
                    max_new_tokens=8) for i in range(8)]
    eng.serve(reqs)
    contiguous_capacity = pool_slots * max_len // max_len
    assert eng.stats["peak_concurrency"] > contiguous_capacity
    assert eng.stats["peak_concurrency"] >= 6    # 16 pages / 2-page reqs
    assert all(r.tokens is not None for r in reqs)
    assert eng._alloc.n_free == eng.n_pages


def test_admission_gated_on_free_pages_fifo():
    """When the head of the queue cannot reserve its worst case, nothing
    behind it jumps the line; the stream still drains as pages free."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=4, max_len=32,
                        decode_block=4, min_bucket=4, page_size=8,
                        n_pages=4)                    # room for ~1 request
    reqs = [Request(rid=i,
                    prompt=np.arange(20, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    # 20 + 4 - 1 positions -> 3 of 4 pages: only the head fits
    assert eng.stats["peak_concurrency"] == 1
    while eng.busy:
        eng.step()
    assert all(r.tokens is not None for r in reqs)
    assert [r.rid for r in eng.drain_completions()] == [0, 1, 2]  # FIFO
    assert eng._alloc.n_free == eng.n_pages


def test_paged_warmup_precompiles_everything():
    """After warmup, paged serving (incl. a chunked admission) retraces
    nothing."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=4, min_bucket=4, page_size=8,
                        chunk_threshold=12)
    reqs = _mixed_stream(cfg)
    eng.warmup(prompt_lens=[len(r.prompt) for r in reqs])
    # the 29-token prompt chunk-admits: no prefill bucket compiled for it
    assert all(b <= 16 for _, b in eng._prefill_fns)
    traces = (eng.stats["prefill_traces"], eng.stats["decode_traces"],
              eng.stats["chunk_traces"])
    eng.serve(reqs)
    assert all(r.tokens is not None for r in reqs)
    assert (eng.stats["prefill_traces"], eng.stats["decode_traces"],
            eng.stats["chunk_traces"]) == traces, eng.stats


def test_paged_rejects_bad_page_size():
    cfg, model, params = _build("llama3.2-1b")
    with pytest.raises(ValueError, match="multiple of"):
        ServingEngine(model, params, max_len=64, page_size=7)


def test_audio_paged_matches_contiguous_bit_identical():
    """Audio paging (unlocked by masking encoder self-attention and
    decoder cross-attention by true encoder length): padded encoder rows
    contribute exact zeros, so the paged layout's dropped writes on
    padding rows are unobservable — greedy tokens match the contiguous
    engine bit for bit and every page drains."""
    cfg, model, params = _build("whisper-base")
    kw = dict(max_batch=3, max_len=64, decode_block=4, min_bucket=4)
    cont = ServingEngine(model, params, **kw)
    r_cont = _mixed_stream(cfg)
    cont.serve(r_cont)

    paged = ServingEngine(model, params, page_size=8, **kw)
    assert paged._paged
    r_paged = _mixed_stream(cfg)
    paged.serve(r_paged)

    for a, b in zip(r_cont, r_paged):
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"audio: rid={a.rid} plen={len(a.prompt)}")
    for key in ("prefill_traces", "decode_traces", "prefill_dispatches",
                "decode_dispatches", "admitted"):
        assert cont.stats[key] == paged.stats[key], \
            (key, cont.stats, paged.stats)
    assert paged._alloc.n_free == paged.n_pages  # full drain


def test_attention_free_family_ignores_paging():
    """xLSTM has no KV to page: the engine falls back to the contiguous
    (pure-state) path and the knob is inert."""
    cfg, model, params = _build("xlstm-1.3b")
    eng = ServingEngine(model, params, max_batch=2, max_len=32,
                        decode_block=4, min_bucket=4, page_size=8)
    assert not eng._paged and eng._alloc is None
    r = Request(rid=0, prompt=np.arange(6, dtype=np.int32) % cfg.vocab,
                max_new_tokens=3)
    eng.serve([r])
    want = _serial_greedy(model, params, r.prompt, 3)
    np.testing.assert_array_equal(np.asarray(r.tokens),
                                  np.asarray(want, np.int32))


def test_chunked_prefill_works_on_contiguous_layout():
    """Chunked prefill is orthogonal to paging: with page_size=None the
    prompt still teacher-forces through the decode loop in the slot's
    contiguous rows, exactly."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=4, min_bucket=4, chunk_threshold=8)
    assert not eng._paged
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=p)
                    .astype(np.int32),
                    max_new_tokens=3)
            for i, p in enumerate([5, 20, 31, 6])]
    eng.serve(reqs)
    assert eng.stats["chunk_admits"] == 2
    for r in reqs:
        want = _serial_greedy(model, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      np.asarray(want, np.int32),
                                      err_msg=f"rid={r.rid}")


def test_request_larger_than_pool_rejected_at_submit():
    """A request whose worst case exceeds the whole pool can never be
    admitted — submit() must reject it instead of deadlocking the queue."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=4, max_len=32,
                        decode_block=4, min_bucket=4, page_size=8,
                        n_pages=2)                    # 16-position pool
    with pytest.raises(ValueError, match="pool"):
        eng.submit(Request(rid=0,
                           prompt=np.arange(20, dtype=np.int32),
                           max_new_tokens=4))


def test_moe_never_chunks_and_stays_exact():
    """Review regression: MoE expert-capacity keep/drop decisions depend
    on the co-batched token set, so teacher-forcing prompt tokens inside
    the shared decode batch would diverge from the solo prefill the
    engine guarantees. The chunk knob must be inert for MoE and outputs
    must match the non-chunked engine exactly."""
    cfg, model, params = _build("moonshot-v1-16b-a3b")
    kw = dict(max_batch=3, max_len=64, decode_block=4, min_bucket=4)
    base = ServingEngine(model, params, page_size=8, **kw)
    r_base = _mixed_stream(cfg)
    base.serve(r_base)

    chunky = ServingEngine(model, params, page_size=8,
                           chunk_threshold=12, **kw)
    assert chunky.chunk_threshold is None        # knob clamped off
    r_chunky = _mixed_stream(cfg)
    chunky.serve(r_chunky)
    assert chunky.stats["chunk_admits"] == 0
    for a, b in zip(r_base, r_chunky):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens),
                                      err_msg=f"rid={a.rid}")
