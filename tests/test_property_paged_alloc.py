"""Property-based tests (hypothesis) on the paged-KV page allocator.

The ``PageAllocator`` is the host-side half of the paged serving engine:
admission reserves a holder's worst-case page count, ``cover()`` hands out
physical pages as the holder's position grows (chunked prefill grows in
``decode_block``-sized strides), ``release()`` returns them at finish.
In-segment admission adds *staged* holders: requests that reserve (and
partially cover) under a per-request ticket before owning a slot, and are
``rekey()``-ed onto the slot the fused segment pulls them into. Under
arbitrary admit/stage/grow/promote/finish interleavings the pool must
never double-book a page, must conserve ``free + staged + live ==
n_pages``, and must return every page at drain.

Optimistic admission (graceful degradation under pressure) adds
``reserve(strict=False)`` — commitments may exceed the pool — plus the
preempt/re-admit cycle: a victim's pages are released while it parks
host-side, and re-admission re-reserves under the same discipline. The
``committed <= n_pages`` invariant intentionally does not hold there;
everything page-level still must (no double-booking, exact free
accounting, clean drain).
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.engine import PageAllocator

# one op per event: (kind, a, b) drives admit / grow / finish against a
# model of live slots kept in the test
OPS = st.lists(
    st.tuples(st.sampled_from(["admit", "grow", "finish"]),
              st.integers(0, 2**31 - 1), st.integers(1, 96)),
    min_size=1, max_size=80)


def _invariants(alloc: PageAllocator):
    live = alloc.live_pages()
    assert len(live) == len(set(live)), "page referenced by two live slots"
    assert all(0 <= p < alloc.n_pages for p in live)
    assert alloc.n_free + len(live) == alloc.n_pages, \
        "free-list + live pages != pool size"
    assert alloc.committed <= alloc.n_pages


@settings(max_examples=150, deadline=None)
@given(OPS, st.integers(1, 48), st.integers(1, 16), st.integers(1, 16))
def test_page_allocator_invariants(ops, n_pages, page_size, max_slots):
    alloc = PageAllocator(n_pages, page_size)
    live = {}                            # slot -> total positions (npos)
    next_slot = 0
    for kind, pick, npos in ops:
        if kind == "admit":
            if next_slot >= max_slots or \
                    not alloc.can_reserve(npos):
                continue
            slot = next_slot
            next_slot += 1
            alloc.reserve(slot, npos)
            live[slot] = npos
            # prompt pages up front, like the engine's admit path
            alloc.cover(slot, min(npos, page_size))
        elif kind == "grow" and live:
            slot = sorted(live)[pick % len(live)]
            # chunked-prefill stride: cover some prefix, never past the
            # reservation (cover clamps, as the engine relies on)
            grown = alloc.cover(slot, npos)
            assert len(alloc.pages_of(slot)) <= \
                alloc.pages_needed(live[slot])
            assert len(grown) == len(set(grown))
        elif kind == "finish" and live:
            slot = sorted(live)[pick % len(live)]
            pages = alloc.release(slot)
            del live[slot]
            assert len(pages) == len(set(pages))
        _invariants(alloc)
    # drain: every page returns to the free list
    for slot in sorted(live):
        alloc.release(slot)
        _invariants(alloc)
    assert alloc.n_free == alloc.n_pages
    assert alloc.committed == 0


@given(st.integers(1, 64), st.integers(1, 32), st.integers(0, 2048))
def test_pages_needed_is_exact_ceiling(n_pages, page_size, npos):
    alloc = PageAllocator(n_pages, page_size)
    need = alloc.pages_needed(npos)
    assert need * page_size >= npos
    assert (need - 1) * page_size < npos or need == 0


STAGE_OPS = st.lists(
    st.tuples(st.sampled_from(["admit", "stage", "grow", "promote",
                               "finish"]),
              st.integers(0, 2**31 - 1), st.integers(1, 96)),
    min_size=1, max_size=80)


@settings(max_examples=150, deadline=None)
@given(STAGE_OPS, st.integers(1, 48), st.integers(1, 16), st.integers(1, 8))
def test_staged_reservations_invariants(ops, n_pages, page_size, max_slots):
    """The engine's in-segment staging discipline: staged tickets hold
    worst-case reservations (first stride covered up front) that gate
    further admission, promote() moves a ticket onto a freed slot, and
    no interleaving double-books a page or loses free+staged+live==pool.
    """
    alloc = PageAllocator(n_pages, page_size)
    live = {}                            # slot -> npos
    staged = {}                          # ticket -> npos
    next_slot, next_ticket = 0, 0
    for kind, pick, npos in ops:
        if kind == "admit":
            if next_slot >= max_slots or not alloc.can_reserve(npos):
                continue
            slot = next_slot
            next_slot += 1
            alloc.reserve(slot, npos)
            live[slot] = npos
            alloc.cover(slot, min(npos, page_size))
        elif kind == "stage":
            if not alloc.can_reserve(npos):
                continue
            ticket = ("stage", next_ticket)
            next_ticket += 1
            alloc.reserve(ticket, npos)
            # first decode_block-ish stride materialized at staging time
            alloc.cover(ticket, min(npos, page_size))
            staged[ticket] = npos
        elif kind == "grow" and live:
            slot = sorted(live)[pick % len(live)]
            grown = alloc.cover(slot, npos)
            assert len(alloc.pages_of(slot)) <= \
                alloc.pages_needed(live[slot])
            assert len(grown) == len(set(grown))
        elif kind == "promote" and staged and live:
            # a live slot finishes mid-segment; the oldest staged ticket
            # takes its place (release then rekey, as the harvest does)
            slot = sorted(live)[pick % len(live)]
            alloc.release(slot)
            del live[slot]
            ticket = sorted(staged)[0]
            alloc.rekey(ticket, slot)
            live[slot] = staged.pop(ticket)
        elif kind == "finish" and live:
            slot = sorted(live)[pick % len(live)]
            pages = alloc.release(slot)
            del live[slot]
            assert len(pages) == len(set(pages))
        # ---- invariants: staged and live holders both count ----------
        held = alloc.live_pages()
        assert len(held) == len(set(held)), "double-booked page"
        staged_pages = sum(len(alloc.pages_of(t)) for t in staged)
        live_pages = sum(len(alloc.pages_of(s)) for s in live)
        assert staged_pages + live_pages == len(held)
        assert alloc.n_free + staged_pages + live_pages == alloc.n_pages, \
            "free + staged + live != pool"
        assert alloc.committed <= alloc.n_pages
    for holder in sorted(staged) + sorted(live):
        alloc.release(holder)
    assert alloc.n_free == alloc.n_pages
    assert alloc.committed == 0


PREEMPT_OPS = st.lists(
    st.tuples(st.sampled_from(["admit", "grow", "preempt", "readmit",
                               "finish"]),
              st.integers(0, 2**31 - 1), st.integers(1, 96)),
    min_size=1, max_size=100)


@settings(max_examples=150, deadline=None)
@given(PREEMPT_OPS, st.integers(1, 48), st.integers(1, 16),
       st.integers(1, 8))
def test_optimistic_preempt_readmit_invariants(ops, n_pages, page_size,
                                               max_slots):
    """The engine's optimistic-admission discipline: reservations are
    strict=False (over-commit allowed), growth is gated by can_cover
    (the pressure probe), preemption releases a victim's pages while it
    parks, and re-admission waits for its full worst case in free pages.
    No interleaving double-books a page, free accounting stays exact at
    every step, and the drain returns the whole pool."""
    alloc = PageAllocator(n_pages, page_size)
    live = {}                            # holder -> npos
    parked = []                          # (holder, npos) FIFO
    next_h = 0
    for kind, pick, npos in ops:
        npos = min(npos, n_pages * page_size)    # submit()-time validation
        if kind == "admit":
            if len(live) >= max_slots:
                continue
            h = ("h", next_h)
            next_h += 1
            # expected usage only: first stride must be free, the rest
            # over-commits
            if alloc.pages_needed(min(npos, page_size)) > alloc.n_free:
                continue
            alloc.reserve(h, npos, strict=False)
            alloc.cover(h, min(npos, page_size))
            live[h] = npos
        elif kind == "grow" and live:
            h = sorted(live)[pick % len(live)]
            if alloc.can_cover(h, npos):
                grown = alloc.cover(h, npos)
                assert len(alloc.pages_of(h)) <= \
                    alloc.pages_needed(live[h])
                assert len(grown) == len(set(grown))
        elif kind == "preempt" and live:
            h = sorted(live)[pick % len(live)]
            pages = alloc.release(h)
            assert len(pages) == len(set(pages))
            parked.append((h, live.pop(h)))
        elif kind == "readmit" and parked:
            h, want = parked[0]
            # hysteresis: the full remaining worst case must sit in
            # actually-free pages (mirrors _admit_pending's parked gate)
            if alloc.pages_needed(want) > alloc.n_free:
                continue
            parked.pop(0)
            alloc.reserve(h, want, strict=False)
            alloc.cover(h, min(want, page_size))
            live[h] = want
        elif kind == "finish" and live:
            h = sorted(live)[pick % len(live)]
            pages = alloc.release(h)
            del live[h]
            assert len(pages) == len(set(pages))
        # page-level invariants hold even while committed > n_pages
        held = alloc.live_pages()
        assert len(held) == len(set(held)), "double-booked page"
        assert alloc.n_free + len(held) == alloc.n_pages
        for h in live:
            assert len(alloc.pages_of(h)) <= alloc.pages_needed(live[h])
    for h in sorted(live):
        alloc.release(h)
    assert alloc.n_free == alloc.n_pages
    assert alloc.committed == 0
    # every parked holder can eventually re-admit into the drained pool
    for h, want in parked:
        assert alloc.pages_needed(want) <= alloc.n_pages
        alloc.reserve(h, want, strict=False)
        alloc.cover(h, want)
        alloc.release(h)
    assert alloc.n_free == alloc.n_pages


@given(st.integers(1, 32), st.integers(1, 8))
def test_reservation_gates_admission(n_pages, page_size):
    """Admitting exactly to capacity succeeds; one page more is refused."""
    alloc = PageAllocator(n_pages, page_size)
    for slot in range(n_pages):
        assert alloc.can_reserve(page_size)
        alloc.reserve(slot, page_size)
    assert not alloc.can_reserve(1)
    with pytest.raises(ValueError):
        alloc.reserve(n_pages + 1, 1)


SHARE_OPS = st.lists(
    st.tuples(st.sampled_from(["admit", "grow", "register", "attach",
                               "cow", "finish"]),
              st.integers(0, 2**31 - 1), st.integers(1, 96)),
    min_size=1, max_size=100)


# the op-driver (and its invariant checks) lives in test_prefix_cache so
# the seeded fuzz mirror there runs even without hypothesis installed
from test_prefix_cache import run_share_ops  # noqa: E402


@settings(max_examples=150, deadline=None)
@given(SHARE_OPS, st.integers(1, 48), st.integers(1, 16), st.integers(1, 8))
def test_refcount_sharing_invariants(ops, n_pages, page_size, max_slots):
    run_share_ops(ops, n_pages, page_size, max_slots)


@settings(max_examples=100, deadline=None)
@given(SHARE_OPS, st.integers(1, 8), st.integers(1, 4))
def test_sharing_under_pressure_evicts_only_cached(ops, n_pages,
                                                   page_size):
    """Tiny pools force the evict path: the on_evict hook's rc==0 assert
    (inside run_share_ops) is what this case exists to exercise."""
    run_share_ops(ops, n_pages, page_size, max_slots=4)
