"""Wall-clock serving runtime (ISSUE 8 tentpole): the control plane on
``RealClock`` with engines stepped by a background thread, streaming
tokens as segments retire.

* flag/constructor validation is cheap and runs in the fast CI job;
* the end-to-end and stress tests build real JAX models (slow).
"""
import threading

import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.api import QueryPayload, QuerySpec
from repro.serving.executor import EngineExecutorConfig
from repro.serving.runtime import ServingRuntime, ThreadedEngineExecutor
from repro.sim.cluster import make_cluster

LLAMA = ARCHS["llama3.2-1b"]
slow = pytest.mark.slow


def test_wall_clock_requires_real_backend():
    with pytest.raises(ValueError, match="backend='real'"):
        make_cluster(n_accel=1, archs=[LLAMA], clock="wall")
    with pytest.raises(ValueError, match="clock"):
        make_cluster(n_accel=1, archs=[LLAMA], clock="lunar")


def test_runtime_rejects_virtual_cluster():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    with pytest.raises(ValueError, match="wall"):
        ServingRuntime(c)


def test_threaded_executor_disables_engine_eviction():
    """LRU engine eviction assumes idle engines between jobs; a threaded
    executor's engines hold in-flight slots, so the cap must be lifted."""
    ex = ThreadedEngineExecutor({LLAMA.name: LLAMA.reduced()},
                                EngineExecutorConfig(max_engines=2))
    assert ex.cfg.max_engines is None
    ex.shutdown()


def _wall_cluster():
    ecfg = EngineExecutorConfig(max_batch=4, max_len=48, decode_block=4)
    return make_cluster(n_accel=1, archs=[LLAMA], autoscale=False,
                        backend="real", clock="wall", engine_cfg=ecfg)


def _spec(rng, n_prompts=1, max_new=10):
    vocab = LLAMA.reduced().vocab
    prompts = [rng.integers(0, vocab,
                            size=int(rng.integers(4, 12))).astype(np.int32)
               for _ in range(n_prompts)]
    return QuerySpec.arch(LLAMA.name, latency_ms=600_000,
                          payload=QueryPayload.of(prompts,
                                                  max_new_tokens=max_new))


@slow
def test_wall_end_to_end_streaming():
    """Live submission from a client thread: tokens stream as segments
    retire, streamed concat is bit-identical to ``result().outputs``,
    TTFT lands at or before completion, and shutdown drains clean."""
    c = _wall_cluster()
    rt = ServingRuntime(c)
    try:
        rng = np.random.default_rng(0)
        handles = [rt.submit(_spec(rng, n_prompts=2)) for _ in range(4)]
        # iter_tokens on a live handle blocks on the cv (wall path)
        it_chunks = list(handles[0].iter_tokens(timeout=600.0))
        results = [h.result(timeout=600.0) for h in handles]
        assert all(r.ok for r in results), \
            [(r.failed, r.variant) for r in results]
        assert it_chunks and [c_.t for c_ in it_chunks] == \
            sorted(c_.t for c_ in it_chunks)
        for h, r in zip(handles, results):
            assert h.chunks, "no streamed chunks"
            for idx, out in enumerate(r.outputs):
                cat = [t for ch in h.chunks if ch.input_idx == idx
                       for t in ch.tokens]
                assert cat == [int(x) for x in out], \
                    "streamed concat != result() outputs"
            assert h.ttft is not None
            assert 0.0 <= h.ttft <= r.latency + 1e-9
    finally:
        assert rt.shutdown(drain=True, timeout=60.0)


@slow
def test_wall_submit_rejects_oversized_prompt():
    """A rejected job surfaces as a failed query, not a hung handle: the
    stepper validates before submitting and reports through on_done."""
    c = _wall_cluster()
    rt = ServingRuntime(c)
    try:
        vocab = LLAMA.reduced().vocab
        too_long = np.arange(60, dtype=np.int32) % vocab   # > max_len 48
        h = rt.submit(QuerySpec.arch(
            LLAMA.name, latency_ms=600_000,
            payload=QueryPayload.of([too_long], max_new_tokens=4)))
        res = h.result(timeout=120.0)
        assert res.failed and not res.ok
    finally:
        rt.shutdown(drain=True, timeout=60.0)


@slow
def test_threaded_executor_two_thread_stress():
    """Satellite 2 acceptance: two threads hammer ``run_async`` while the
    stepper drains — every job completes exactly once, every request's
    outputs are delivered exactly once, nothing is lost or duplicated."""
    ex = ThreadedEngineExecutor(
        {LLAMA.name: LLAMA.reduced()},
        EngineExecutorConfig(max_batch=4, max_len=48, decode_block=4,
                             stream=True))
    from repro.core import profiler as prof
    variant = next(v for v in prof.generate_variants(LLAMA)
                   if v.hardware in ("cpu-host", "tpu-v5e-1"))
    vocab = LLAMA.reduced().vocab
    n_per_thread = 8
    lock = threading.Lock()
    done = []          # (thread, job_idx, duration | error)
    outputs = {}       # (thread, job_idx) -> delivery count

    def hammer(tid):
        from repro.core.worker import ExecRequest
        rng = np.random.default_rng(tid)
        for j in range(n_per_thread):
            key = (tid, j)

            def on_outputs(outs, key=key):
                with lock:
                    outputs[key] = outputs.get(key, 0) + 1

            def on_done(duration, error=None, key=key):
                with lock:
                    done.append((key, duration, error))

            prompt = rng.integers(0, vocab, size=int(rng.integers(4, 10)))
            er = ExecRequest(n_inputs=1,
                             prompts=(tuple(int(x) for x in prompt),),
                             max_new_tokens=int(rng.integers(2, 8)),
                             on_outputs=on_outputs)
            ex.run_async(variant, 1, [er], on_done)

    threads = [threading.Thread(target=hammer, args=(tid,))
               for tid in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ex.shutdown(timeout=300.0)     # drains every queued job before stopping

    total = 2 * n_per_thread
    assert len(done) == total, f"lost/duplicated completions: {done}"
    assert all(err is None for _, _, err in done), done
    assert len({key for key, _, _ in done}) == total, "duplicate on_done"
    assert set(outputs) == {(t, j) for t in range(2)
                            for j in range(n_per_thread)}
    assert all(n == 1 for n in outputs.values()), "outputs delivered twice"
    # after the drain nothing is left in flight
    assert not ex._active and not ex._sinks and not ex._req_job
