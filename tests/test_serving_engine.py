"""Real-execution serving engine on host with reduced-config models.

Covers the continuous-batching engine's two core guarantees:
* greedy outputs are token-for-token identical to serial per-request decode
  (mixed prompt lengths and mixed max_new, across model families), and
* compilation is bounded by shape buckets — at most one prefill executable
  per prompt bucket and one decode-segment executable per engine, across
  mixed-shape request streams.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine, WaveEngine

# every test here builds and decodes real JAX models (fast CI deselects
# slow; the full tier-1 run still covers them)
pytestmark = pytest.mark.slow


def _serial_greedy(model, params, prompt, max_new):
    """Oracle: greedy rollout with full forward() per step, one request."""
    toks = list(map(int, prompt))
    for _ in range(max_new):
        logits = model.forward(params,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def _build(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_greedy_matches_manual_decode():
    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=4)
    prompt = np.arange(6, dtype=np.int32) % cfg.vocab
    reqs = [Request(rid=0, prompt=prompt, max_new_tokens=4)]
    out = eng.serve(reqs)
    assert out[0].tokens is not None and len(out[0].tokens) == 4
    # manual greedy rollout with forward() must agree
    import jax.numpy as jnp
    toks = list(prompt)
    for _ in range(4):
        logits = model.forward(params,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(out[0].tokens, np.asarray(toks[6:]))


def test_engine_adaptive_batching_waves():
    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=3)
    reqs = [Request(rid=i, prompt=np.arange(4 + i % 3, dtype=np.int32))
            for i in range(7)]
    out = eng.serve(reqs)
    assert len(out) == 7
    assert all(r.tokens is not None for r in out)


# dense + ssm (ISSUE requirement) + the hybrid family, which exercises the
# masked-recurrence prefill (SSD dt masking + conv-tail gather) as well
@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
def test_continuous_batching_matches_serial_greedy(arch):
    """Token-for-token equivalence vs serial decode under mixed shapes.

    More requests than slots, mixed prompt lengths, and mixed max_new force
    mid-flight slot refill — the outputs must still be bit-identical to
    decoding each request alone.
    """
    cfg, model, params = _build(arch)
    eng = ServingEngine(model, params, max_batch=3, max_len=64,
                        decode_block=4, min_bucket=4)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(3, 12))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 6)))
            for i in range(6)]
    out = eng.serve(reqs)
    for r in out:
        want = _serial_greedy(model, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(
            np.asarray(r.tokens), np.asarray(want, np.int32),
            err_msg=f"{arch}: rid={r.rid} plen={len(r.prompt)} "
                    f"max_new={r.max_new_tokens}")


def test_compile_count_bounded_by_buckets():
    """<= one prefill trace per (bucket_batch, bucket_len) pair and one
    decode trace per engine, across mixed-shape request streams."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=4, max_len=64,
                        decode_block=4, min_bucket=4)
    plens = [3, 5, 8, 9, 16, 2, 11, 4]           # len buckets: {4, 8, 16}
    reqs = [Request(rid=i, prompt=np.arange(p, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=3) for i, p in enumerate(plens)]
    eng.serve(reqs)
    # exactly one trace per compiled (bucket_batch, bucket_len) executable,
    # bounded by 3 len buckets x 2 admit-batch buckets; one decode program
    assert eng.stats["prefill_traces"] == len(eng._prefill_fns), eng.stats
    assert eng.stats["prefill_traces"] <= 6, eng.stats
    assert {b for _, b in eng._prefill_fns} == {4, 8, 16}
    assert eng.stats["decode_traces"] == 1, eng.stats
    # an identical mixed-shape stream must not recompile anything
    before = dict(eng.stats)
    reqs2 = [Request(rid=100 + i,
                     prompt=np.arange(p, dtype=np.int32) % cfg.vocab,
                     max_new_tokens=3) for i, p in enumerate(plens)]
    eng.serve(reqs2)
    assert eng.stats["prefill_traces"] == before["prefill_traces"], eng.stats
    assert eng.stats["decode_traces"] == before["decode_traces"], eng.stats


def test_warmup_precompiles_service_shapes():
    """After warmup, serving on covered buckets triggers zero retraces."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=4, min_bucket=4)
    eng.warmup(prompt_lens=[5, 12])
    traces = (eng.stats["prefill_traces"], eng.stats["decode_traces"])
    reqs = [Request(rid=i, prompt=np.arange(p, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=2) for i, p in enumerate([4, 6, 9, 12])]
    out = eng.serve(reqs)
    assert all(r.tokens is not None and len(r.tokens) == 2 for r in out)
    assert (eng.stats["prefill_traces"], eng.stats["decode_traces"]) \
        == traces, eng.stats


def test_wave_engine_baseline_still_serves():
    """The seed-style baseline stays importable and correct (benchmarks)."""
    cfg, model, params = _build("llama3.2-1b")
    eng = WaveEngine(model, params, max_batch=4)
    prompt = np.arange(6, dtype=np.int32) % cfg.vocab
    out = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    want = _serial_greedy(model, params, prompt, 4)
    np.testing.assert_array_equal(np.asarray(out[0].tokens),
                                  np.asarray(want, np.int32))
