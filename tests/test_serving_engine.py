"""Real-execution serving engine on host with a reduced-config model."""
import numpy as np

import jax

from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def test_engine_greedy_matches_manual_decode():
    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=4)
    prompt = np.arange(6, dtype=np.int32) % cfg.vocab
    reqs = [Request(rid=0, prompt=prompt, max_new_tokens=4)]
    out = eng.serve(reqs)
    assert out[0].tokens is not None and len(out[0].tokens) == 4
    # manual greedy rollout with forward() must agree
    import jax.numpy as jnp
    toks = list(prompt)
    for _ in range(4):
        logits = model.forward(params,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(out[0].tokens, np.asarray(toks[6:]))


def test_engine_adaptive_batching_waves():
    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=3)
    reqs = [Request(rid=i, prompt=np.arange(4 + i % 3, dtype=np.int32))
            for i in range(7)]
    out = eng.serve(reqs)
    assert len(out) == 7
    assert all(r.tokens is not None for r in out)
