"""In-segment admission: the staging ring must change *when* requests are
admitted (inside the fused decode loop, with zero extra dispatches) without
changing *what* they decode.

Pins the tentpole's guarantees (ISSUE 5 acceptance):

* equivalence — greedy outputs with ``stage_slots=N`` are bit-identical to
  boundary-only admission (``stage_slots=0``) for dense + ssm + hybrid on
  both the contiguous and paged layouts;
* zero added dispatches — the staged requests ride inside the existing
  fused segments: one decode trace per engine, decode dispatches == host
  ``step()`` calls, and staged requests never prefill;
* multi-completion — one slot retires two short requests in one segment
  (one dispatch), with the completion log splitting the emission row;
* page hygiene — staged requests hold worst-case reservations from
  staging time, reservations promote to the slot at harvest, and a full
  drain returns every page;
* occupancy accounting — busy + bubble slot-steps partition the segment
  exactly, and ``EngineExecutor`` threads per-run occupancy into its
  decision log.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models import build_model

# every test here builds and decodes real JAX models (fast CI deselects
# slow; the full tier-1 run still covers them)
pytestmark = pytest.mark.slow
from repro.serving.engine import Request, ServingEngine  # noqa: E402

_BUILT = {}


def _build(arch):
    if arch not in _BUILT:
        cfg = ARCHS[arch].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _BUILT[arch] = (cfg, model, params)
    return _BUILT[arch]


def _serial_greedy(model, params, prompt, max_new):
    toks = list(map(int, prompt))
    for _ in range(max_new):
        logits = model.forward(params,
                               {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def _stream(cfg, n=8, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(3, 10))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(1, 6)))
            for i in range(n)]


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
@pytest.mark.parametrize("page_size", [None, 8])
def test_inseg_matches_boundary_bit_identical(arch, page_size):
    """Same stream, same engine config, stage_slots on vs off: identical
    greedy tokens per request on both layouts (xLSTM has no KV to page —
    the paged knob is inert there, which this still exercises)."""
    cfg, model, params = _build(arch)
    kw = dict(max_batch=2, max_len=64, decode_block=8, min_bucket=4)
    if page_size is not None:
        kw["page_size"] = page_size
    boundary = ServingEngine(model, params, stage_slots=0, **kw)
    r0 = _stream(cfg)
    boundary.serve(r0)
    assert boundary.stats["inseg_admissions"] == 0

    inseg = ServingEngine(model, params, stage_slots=4, **kw)
    r1 = _stream(cfg)
    inseg.serve(r1)
    assert inseg.stats["inseg_admissions"] > 0, inseg.stats
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"{arch} ps={page_size}: rid={a.rid}")
    # one decode program, and staged requests never prefilled: strictly
    # fewer prefill dispatches than the boundary engine
    assert inseg.stats["decode_traces"] == 1
    assert inseg.stats["prefill_dispatches"] < \
        boundary.stats["prefill_dispatches"]
    assert inseg.stats["admitted"] == boundary.stats["admitted"] == len(r0)
    if inseg._paged:
        assert inseg._alloc.n_free == inseg.n_pages    # full drain


def test_multi_completion_one_slot_one_segment():
    """Two short requests retired by ONE slot in ONE fused dispatch: the
    first prefills, the second stages, and the loop pulls it into the
    freed slot mid-segment. Pinned: 1 prefill + 1 decode dispatch total,
    both outputs exact."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=1, max_len=64,
                        decode_block=16, min_bucket=4, stage_slots=2)
    r1 = Request(rid=1, prompt=np.arange(4, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=3)
    r2 = Request(rid=2, prompt=np.arange(3, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=3)
    eng.submit(r1)
    eng.submit(r2)
    eng.step()
    assert r1.tokens is not None and r2.tokens is not None
    assert eng.stats["decode_dispatches"] == 1, eng.stats
    assert eng.stats["prefill_dispatches"] == 1, eng.stats
    assert eng.stats["inseg_admissions"] == 1, eng.stats
    assert [r.rid for r in eng.drain_completions()] == [1, 2]
    for r in (r1, r2):
        want = _serial_greedy(model, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      np.asarray(want, np.int32),
                                      err_msg=f"rid={r.rid}")


def test_inseg_zero_added_dispatches_per_segment():
    """Decode dispatches == host step() calls whether or not the ring is
    populated: admissions happen inside existing segments, never as extra
    dispatches."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=8, min_bucket=4, stage_slots=4)
    for r in _stream(cfg):
        eng.submit(r)
    steps = 0
    while eng.busy:
        eng.step()
        steps += 1
    assert eng.stats["decode_dispatches"] == steps
    assert eng.stats["decode_traces"] == 1
    assert eng.stats["inseg_admissions"] > 0


def test_inseg_mid_stream_submit_is_staged():
    """A request submitted while slots are full is staged between segments
    and admitted inside the next one (no step() boundary wait for a free
    slot, no prefill dispatch)."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=1, max_len=64,
                        decode_block=16, min_bucket=4, stage_slots=2)
    r1 = Request(rid=1, prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=4)
    eng.submit(r1)
    eng._admit_pending()                 # r1 takes the only slot
    r2 = Request(rid=2, prompt=np.arange(3, dtype=np.int32) % cfg.vocab,
                 max_new_tokens=2)
    eng.submit(r2)                       # arrives mid-decode, slotless
    pf = eng.stats["prefill_dispatches"]
    while eng.busy:
        eng.step()
    assert eng.stats["prefill_dispatches"] == pf        # r2 never prefilled
    assert eng.stats["staged"] == 1 and eng.stats["inseg_admissions"] == 1
    for r in (r1, r2):
        want = _serial_greedy(model, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      np.asarray(want, np.int32),
                                      err_msg=f"rid={r.rid}")
    assert r2.admitted >= r2.arrival >= 0.0


def test_staged_request_not_stranded_by_sweep_freed_slot():
    """Review regression: a max_new==1 prefill finishes AT admission
    (rem==0, swept at harvest without passing through the loop's refill
    logic). The staged request behind it must be seated into the freed
    slot at the next boundary instead of stranding in the ring forever
    (busy=True livelock)."""
    cfg, model, params = _build("llama3.2-1b")
    for page_size in (None, 8):
        kw = dict(max_batch=1, max_len=32, decode_block=8, min_bucket=4,
                  stage_slots=2)
        if page_size is not None:
            kw["page_size"] = page_size
        eng = ServingEngine(model, params, **kw)
        r1 = Request(rid=1, prompt=np.arange(4, dtype=np.int32) % cfg.vocab,
                     max_new_tokens=1)
        r2 = Request(rid=2, prompt=np.arange(3, dtype=np.int32) % cfg.vocab,
                     max_new_tokens=3)
        eng.submit(r1)
        eng.submit(r2)
        for _ in range(16):
            if not eng.busy:
                break
            eng.step()
        assert not eng.busy, "staged request stranded (livelock)"
        assert r1.tokens is not None and r2.tokens is not None
        assert [r.rid for r in eng.drain_completions()] == [1, 2]
        for r in (r1, r2):
            want = _serial_greedy(model, params, r.prompt,
                                  r.max_new_tokens)
            np.testing.assert_array_equal(
                np.asarray(r.tokens), np.asarray(want, np.int32),
                err_msg=f"ps={page_size} rid={r.rid}")
        if eng._paged:
            assert eng._alloc.n_free == eng.n_pages


def test_staged_requests_hold_page_reservations():
    """Paged mode: a staged request reserves its worst case at staging
    time (its pages visible to the allocator before it ever owns a slot),
    boundary admission cannot overcommit past staged reservations, and a
    full drain returns every page."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=1, max_len=32,
                        decode_block=8, min_bucket=4, page_size=8,
                        n_pages=4, stage_slots=4)
    # each request needs ceil((5 + 4 - 1) / 8) = 1 page
    reqs = [Request(rid=i, prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=4) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng._admit_pending()
    # 1 slot + 3 staged = 4 reserved pages; the rest wait in pending
    assert eng._alloc.committed == 4
    assert len(eng._staged) == 3 and len(eng._pending) == 2
    while eng.busy:
        eng.step()
    assert all(r.tokens is not None for r in reqs)
    assert [r.rid for r in eng.drain_completions()] == list(range(6))
    assert eng._alloc.n_free == eng.n_pages
    assert eng._alloc.committed == 0


def test_occupancy_accounting_partitions_segments():
    """busy + bubble slot-steps partition the executed segment steps
    exactly, and admissions-per-segment reflects in-segment refills only.
    (The busy-fraction *gain* under sustained load is the benchmark's
    claim — ``--scenario churn``; a drain tail can legitimately lower the
    aggregate fraction.)"""
    cfg, model, params = _build("llama3.2-1b")
    kw = dict(max_batch=2, max_len=64, decode_block=16, min_bucket=4)
    for stage in (0, 4):
        eng = ServingEngine(model, params, stage_slots=stage, **kw)
        eng.serve(_stream(cfg))
        s = eng.stats
        assert s["busy_slot_steps"] + s["bubble_slot_steps"] == \
            s["decode_steps"] * eng.max_batch, s
        occ = eng.occupancy
        assert 0.0 < occ["slot_busy_frac"] <= 1.0
        assert occ["segments"] == s["decode_dispatches"]
        if stage:
            assert occ["admissions_per_segment"] > 0.0
            assert 0 < s["inseg_admissions"] <= s["admitted"]
        else:
            assert occ["admissions_per_segment"] == 0.0


def test_stage_slots_clamped_for_ineligible_families():
    """MoE (capacity routing) and audio/vlm (encoder KV from prefill)
    cannot teacher-force staged prompts: the knob clamps to boundary-only
    and outputs stay exact."""
    cfg, model, params = _build("moonshot-v1-16b-a3b")
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=8, min_bucket=4, stage_slots=4)
    assert eng.stage_slots == 0
    r0 = _stream(cfg, n=4)
    eng.serve(r0)
    assert eng.stats["inseg_admissions"] == 0
    base = ServingEngine(model, params, max_batch=2, max_len=64,
                         decode_block=8, min_bucket=4)
    r1 = _stream(cfg, n=4)
    base.serve(r1)
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))


def test_xlstm_chunked_prefill_via_empty_state():
    """The empty_state() seam unlocks chunked prefill for xLSTM: prompts
    past the threshold teacher-force through the decode loop from the
    -inf-stabilizer empty state and match boundary prefill exactly."""
    cfg, model, params = _build("xlstm-1.3b")
    kw = dict(max_batch=2, max_len=64, decode_block=4, min_bucket=4)
    base = ServingEngine(model, params, **kw)
    rng = np.random.default_rng(7)

    def stream():
        rng = np.random.default_rng(7)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, size=p)
                        .astype(np.int32),
                        max_new_tokens=3)
                for i, p in enumerate([5, 20, 31, 6])]

    rb = stream()
    base.serve(rb)
    chunky = ServingEngine(model, params, chunk_threshold=8, **kw)
    assert chunky.chunk_threshold == 8          # no longer clamped off
    rc = stream()
    chunky.serve(rc)
    assert chunky.stats["chunk_admits"] == 2, chunky.stats
    for a, b in zip(rb, rc):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens),
                                      err_msg=f"rid={a.rid}")


def test_xlstm_empty_state_matches_scan_defaults():
    """xlstm_empty_state must reproduce the state the recurrent cells
    initialize from (state=None): a greedy rollout seeded from the seam
    (decode-only, token by token) matches the prefill+decode rollout."""
    from repro.models.xlstm import xlstm_empty_state
    cfg, model, params = _build("xlstm-1.3b")
    prompt = [3, 5, 2, 7]
    # rollout A: teacher-force the prompt through decode from empty state
    cache = xlstm_empty_state(cfg, 1)
    pos = jnp.zeros((1,), jnp.int32)
    for t in prompt:
        logits, cache = model.decode(
            params, cache, jnp.asarray([[t]], jnp.int32), pos)
        pos = pos + 1
    got = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(3):
        logits, cache = model.decode(
            params, cache, jnp.asarray([[got[-1]]], jnp.int32), pos)
        pos = pos + 1
        got.append(int(jnp.argmax(logits[0, -1])))
    # rollout B: standard prefill + decode
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    want = [int(jnp.argmax(logits[0, -1]))]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(3):
        logits, cache = model.decode(
            params, cache, jnp.asarray([[want[-1]]], jnp.int32), pos)
        pos = pos + 1
        want.append(int(jnp.argmax(logits[0, -1])))
    assert got == want


def test_executor_threads_occupancy_into_decision_log():
    """EngineExecutor passes stage_slots through and appends a per-run
    occupancy record (the executor's decision log)."""
    from repro.core import profiler as prof
    from repro.serving.executor import EngineExecutor, EngineExecutorConfig
    acfg = ARCHS["llama3.2-1b"]
    variants = prof.generate_variants(acfg)
    v = next(x for x in variants if x.hardware == "cpu-host")
    ex = EngineExecutor({acfg.name: acfg.reduced()},
                        EngineExecutorConfig(max_batch=2, max_len=32,
                                             decode_block=8,
                                             stage_slots=2))
    ex.run(v, batch=4)
    eng = ex.engines[v.name]
    assert eng.stage_slots == 2
    assert len(ex.occupancy_log) == 1
    rec = ex.occupancy_log[0]
    assert rec["variant"] == v.name
    assert 0.0 < rec["slot_busy_frac"] <= 1.0
    assert rec["segments"] >= 1
    ex.run(v, batch=2)
    assert len(ex.occupancy_log) == 2
