"""Training substrate: loss decreases, checkpoint/restart resumes exactly,
gradient compression stays close to exact training."""
import numpy as np
import pytest

import jax

from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.training import data as data_lib
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


def _cfg():
    return ARCHS["llama3.2-1b"].reduced()


def _dcfg():
    return data_lib.DataConfig(batch=4, seq=32, seed=0)


def test_loss_decreases():
    model = build_model(_cfg())
    tcfg = TrainConfig(opt=AdamWConfig(lr=2e-3, warmup_steps=5,
                                       total_steps=60))
    out = train(model, _dcfg(), steps=60, tcfg=tcfg)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.3, (first, last)


def test_checkpoint_restart_exact(tmp_path):
    model = build_model(_cfg())
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                       total_steps=30), ckpt_every=10)
    ckpt = str(tmp_path / "run")
    # crash at step 17 (after the step-10 checkpoint)
    with pytest.raises(RuntimeError, match="injected failure"):
        train(model, _dcfg(), steps=30, tcfg=tcfg, ckpt_dir=ckpt,
              fail_at_step=17)
    out = train(model, _dcfg(), steps=30, tcfg=tcfg, ckpt_dir=ckpt)
    assert out["resumed_from"] == 10
    # a run with no failure must produce identical final params
    clean = train(model, _dcfg(), steps=30, tcfg=tcfg)
    a = jax.tree.leaves(out["state"]["params"])
    b = jax.tree.leaves(clean["state"]["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_grad_compression_trains():
    model = build_model(_cfg())
    tcfg = TrainConfig(opt=AdamWConfig(lr=2e-3, warmup_steps=5,
                                       total_steps=40),
                       grad_compression=True)
    out = train(model, _dcfg(), steps=40, tcfg=tcfg)
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])


def test_data_restart_determinism():
    cfg, dcfg = _cfg(), _dcfg()
    b1 = data_lib.batch_at_step(cfg, dcfg, 123)
    b2 = data_lib.batch_at_step(cfg, dcfg, 123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data_lib.batch_at_step(cfg, dcfg, 124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
