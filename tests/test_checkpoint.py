"""Checkpoint subsystem: atomic save/restore, corruption detection,
retention, and crash-restart semantics."""
import os

import jax
import numpy as np
import pytest

from repro.distributed import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": rng.normal(size=(4, 8)).astype(np.float32),
                   "b": rng.normal(size=(8,)).astype(np.float32)},
        "head": rng.normal(size=(8, 2)).astype(np.float32),
        "step_count": np.asarray(7, np.int32),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    p = str(tmp_path / "c0")
    ckpt.save_pytree(p, t)
    back = ckpt.load_pytree(p, like=t)
    jax.tree.map(np.testing.assert_array_equal, t, back)


def test_corruption_detected(tmp_path):
    t = _tree()
    p = str(tmp_path / "c1")
    ckpt.save_pytree(p, t)
    # flip bytes in the array file
    npz = os.path.join(p, "arrays.npz")
    data = dict(np.load(npz))
    key = sorted(data)[0]
    data[key] = data[key] + 1.0
    np.savez(npz, **data)
    with pytest.raises(IOError, match="corruption"):
        ckpt.load_pytree(p, like=t)


def test_manager_retention_and_restore(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path / "run"), keep=2)
    for step in (10, 20, 30, 40):
        mgr.save(step, _tree(step))
    assert mgr.all_steps() == [30, 40]
    step, tree = mgr.restore(like=_tree())
    assert step == 40
    jax.tree.map(np.testing.assert_array_equal, tree, _tree(40))


def test_manager_restart_after_partial_write(tmp_path):
    """A torn write (no manifest) must be invisible to restore."""
    mgr = ckpt.CheckpointManager(str(tmp_path / "run"), keep=3)
    mgr.save(1, _tree(1))
    torn = os.path.join(str(tmp_path / "run"), "step_000000002")
    os.makedirs(torn)           # directory exists, but no manifest.json
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(like=_tree())
    assert step == 1
