"""Per-architecture smoke tests: reduced config, one forward / prefill /
decode step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import build_model, make_batch

BATCH, SEQ = 2, 32


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_smoke(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(cfg, rng, BATCH, SEQ)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"NaN/Inf in {arch} logits"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_loss_and_grad_smoke(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(cfg, rng, BATCH, SEQ)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), \
        f"non-finite grad in {arch}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_smoke(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(cfg, rng, BATCH, SEQ, with_targets=False)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one decode step. Attention caches from prefill have length SEQ; the
    # decode step writes at pos == SEQ - 1 is out of range for fresh token,
    # so decode against a cache padded to SEQ + 1 via cache_shapes alloc.
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    padded = _pad_cache(cache, model, SEQ + 8)
    logits2, cache2 = jax.jit(model.decode)(params, padded, tok, SEQ)
    assert logits2.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert jax.tree.structure(cache2) == jax.tree.structure(padded)


def _pad_cache(cache, model, max_len, batch=BATCH, enc_len=SEQ):
    """Pad attention KV buffers (dim with size == prefill seq) to max_len."""
    shapes = model.cache_shapes(batch, max_len, enc_len=enc_len)

    def pad(c, target):
        if c.shape == target.shape:
            return c.astype(target.dtype)
        pads = [(0, t - s) for s, t in zip(c.shape, target.shape)]
        return jnp.pad(c, pads).astype(target.dtype)

    return jax.tree.map(pad, cache, shapes)


def test_decode_matches_forward_dense(rng):
    """Greedy consistency: decode logits at step t == forward logits at t."""
    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(cfg, rng, 1, 8, with_targets=False)
    full = model.forward(params, batch)  # (1, 8, V)
    # prefill on the first 7 tokens, then decode token 7
    pre = {"tokens": batch["tokens"][:, :7]}
    logits, cache = model.prefill(params, pre)
    np.testing.assert_allclose(np.asarray(logits[0, 0]),
                               np.asarray(full[0, 6]), rtol=2e-4, atol=2e-4)
    padded = _pad_cache(cache, model, 16, batch=1)
    tok = batch["tokens"][:, 7:8]
    logits2, _ = model.decode(params, padded, tok, 7)
    np.testing.assert_allclose(np.asarray(logits2[0, 0]),
                               np.asarray(full[0, 7]), rtol=2e-4, atol=2e-4)
