"""Clock abstraction symmetry (ISSUE 8 satellite): both clocks implement
the full scheduling surface — ``schedule`` / ``schedule_at`` / ``every`` /
``next_event_time`` — so control-plane code written against ``Clock`` runs
unchanged under the discrete-event ``EventLoop`` or the threaded
``RealClock``."""
import threading
import time

from repro.sim.clock import Clock, EventLoop, RealClock


def test_both_clocks_expose_the_same_surface():
    for loop in (EventLoop(), RealClock()):
        for name in ("now", "schedule", "schedule_at", "every",
                     "next_event_time", "run_until", "shutdown"):
            assert callable(getattr(loop, name, None)), \
                f"{type(loop).__name__} missing {name}"
        if isinstance(loop, RealClock):
            loop.shutdown()
    assert EventLoop.virtual is True
    assert RealClock.virtual is False
    assert Clock.virtual is True      # default matches the sim path


def test_eventloop_every_applies_jitter_to_every_interval():
    """jitter is a per-task phase offset on *each* firing, not just the
    first: two tasks with equal period but different jitter must never
    collapse onto the same firing times."""
    loop = EventLoop()
    a, b = [], []
    loop.every(10.0, lambda: a.append(loop.now()), jitter=1.0)
    loop.every(10.0, lambda: b.append(loop.now()), jitter=3.0)
    loop.run_until(70.0)
    assert a == [11.0, 22.0, 33.0, 44.0, 55.0, 66.0]
    assert b == [13.0, 26.0, 39.0, 52.0, 65.0]
    assert not set(a) & set(b)


def test_eventloop_every_stop_predicate():
    loop = EventLoop()
    fired = []
    loop.every(5.0, lambda: fired.append(loop.now()),
               stop=lambda: loop.now() > 12.0)
    loop.run_until(100.0)
    assert fired == [5.0, 10.0]


def test_realclock_schedule_fires_in_deadline_order():
    loop = RealClock()
    try:
        fired = []
        done = threading.Event()
        loop.schedule(0.10, lambda: (fired.append("late"), done.set()))
        loop.schedule(0.01, lambda: fired.append("early"))
        loop.schedule(0.05, lambda: fired.append("mid"))
        assert done.wait(5.0)
        assert fired == ["early", "mid", "late"]
    finally:
        loop.shutdown()


def test_realclock_now_and_next_event_time():
    loop = RealClock()
    try:
        t = loop.now()
        assert t >= 0.0
        assert loop.next_event_time() is None
        loop.schedule_at(t + 60.0, lambda: None)
        nxt = loop.next_event_time()
        assert nxt is not None and nxt >= t + 59.0
        assert loop.pending() == 1
    finally:
        loop.shutdown()


def test_realclock_callbacks_may_schedule_more_work():
    """every() chains tick -> schedule -> tick on the scheduler thread;
    the lock must be released during callbacks for this to make progress."""
    loop = RealClock()
    try:
        fired = []
        enough = threading.Event()

        def tick():
            fired.append(loop.now())
            if len(fired) >= 3:
                enough.set()

        loop.every(0.01, tick, stop=enough.is_set)
        assert enough.wait(5.0)
        assert len(fired) >= 3
        assert fired == sorted(fired)
    finally:
        loop.shutdown()


def test_realclock_survives_raising_callback():
    loop = RealClock()
    try:
        ok = threading.Event()
        loop.schedule(0.0, lambda: 1 / 0)
        loop.schedule(0.02, ok.set)
        assert ok.wait(5.0), "scheduler died after a raising callback"
    finally:
        loop.shutdown()


def test_realclock_shutdown_drops_pending_and_rejects_new_work():
    loop = RealClock()
    fired = []
    loop.schedule(30.0, lambda: fired.append("too late"))
    loop.shutdown()
    assert loop.pending() == 0
    loop.schedule(0.0, lambda: fired.append("after stop"))   # no-op
    time.sleep(0.05)
    assert fired == []


def test_realclock_run_until_blocks_while_events_fire():
    loop = RealClock()
    try:
        fired = []
        loop.schedule(0.03, lambda: fired.append(loop.now()))
        t0 = loop.now()
        loop.run_until(t0 + 0.08)
        assert loop.now() >= t0 + 0.08
        assert len(fired) == 1
    finally:
        loop.shutdown()
