"""Hypothesis property sweeps for the Pallas kernels (interpret mode):
random shapes within the kernels' block constraints, allclose vs ref."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2]),
       st.sampled_from([(2, 1), (2, 2), (4, 1)]),
       st.sampled_from([128, 256]), st.sampled_from([64, 128]),
       st.booleans())
def test_flash_attention_property(seed, B, kg, S, D, causal):
    K, G = kg
    H = K * G
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, K, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, K, S, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 3]),
       st.sampled_from([(1, 4), (2, 2), (4, 1)]),
       st.sampled_from([512, 1024]), st.integers(1, 1024))
def test_decode_attention_property(seed, B, kg, T, valid):
    K, G = kg
    valid = min(valid, T)
    rng = np.random.default_rng(seed)
    D = 64
    q = jnp.asarray(rng.normal(size=(B, K, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, K, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, K, T, D)), jnp.float32)
    out = decode_attention(q, k, v, valid_len=jnp.int32(valid),
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(128, 256, 128), (128, 512, 256), (256, 256, 128)]))
def test_int8_matmul_property(seed, mkn):
    M, Kd, N = mkn
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(M, Kd)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(Kd, N)), jnp.float32)
    w_q, scales = ref.quantize_int8(w)
    out = int8_matmul(x, w_q, scales, interpret=True)
    want = ref.int8_matmul_ref(x, w_q, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
    # quantization error itself is bounded (property of the int8 scheme)
    dense = x @ w
    rel = np.linalg.norm(np.asarray(out) - np.asarray(dense)) / \
        np.linalg.norm(np.asarray(dense))
    assert rel < 0.02, rel
