"""Integration tests: master + workers + two-level autoscaler + offline
sharing + fault tolerance, on the discrete-event cluster."""
import pytest

from repro.configs.registry import ARCHS
from repro.core.master import MasterConfig
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals

LLAMA = ARCHS["llama3.2-1b"]
ZAMBA = ARCHS["zamba2-1.2b"]


def _done(q):
    return q.finish >= 0 and not q.failed


def test_online_query_lifecycle():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    q = c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000)
    c.run_until(60.0)
    assert _done(q), (q.failed, q.finish)
    v = c.store.registry.variants[q.variant]
    # cold query: latency ~ load + inference (+ dispatch slack)
    expected = v.profile.load_latency + v.profile.latency(1)
    assert q.latency == pytest.approx(expected, rel=0.5)
    # decision overhead was recorded
    assert c.master.decision_log and c.master.decision_log[0][0] == "modarch"


def test_warm_queries_are_fast_and_cached():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000)
    # stay inside the T_accel=20s scale-down hysteresis so the loaded
    # variant is still resident (beyond it, the worker autoscaler correctly
    # downgrades the idle variant and invalidates the cache)
    c.run_until(8.0)
    q2 = c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000)
    c.run_until(10.0)
    assert _done(q2)
    v = c.store.registry.variants[q2.variant]
    assert q2.latency < 0.1 + v.profile.latency(1) * 3
    assert c.master.decision_log[-1][0] == "modarch"
    # second identical query must come from the decision cache
    sel = c.master.selector.select_arch(LLAMA.name, 1, 5.0)
    assert sel.outcome == "cache"


def test_idle_accel_variant_downgrades_over_time():
    """Zero load: the worker autoscaler walks the variant down the batch
    ladder (b16 -> ... -> b1 -> CPU eventually), T_accel ticks per rung."""
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    q = c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000)
    c.run_until(220.0)
    assert _done(q)
    w = next(iter(c.master.workers.values()))
    # after repeated hysteresis windows with zero load, nothing should be
    # left occupying the accelerator
    accel_left = [li.variant.name for li in w.instances.values()
                  if li.variant.is_accel]
    assert not accel_left, accel_left


def test_adaptive_batching_under_burst():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    v = [x for x in c.store.registry.variants.values()
         if x.hardware == "tpu-v5e-1" and x.batch_opt == 8
         and "bf16" in x.framework][0]
    w = next(iter(c.master.workers.values()))
    w.load_variant(v)
    c.run_until(10.0)
    qs = [c.api.online_query(mod_var=v.name, latency_ms=5000)
          for _ in range(64)]
    c.run_until(20.0)
    assert all(_done(q) for q in qs)
    serial = 64 * v.profile.latency(1)
    makespan = max(q.finish for q in qs) - min(q.arrival for q in qs)
    # adaptive batching packs 8 requests/job: ~8 jobs of t(8) << 64 x t(1)
    assert makespan < serial * 0.6, (makespan, serial)


def test_worker_autoscaler_replicates_on_cpu():
    c = make_cluster(n_accel=0, n_cpu=1, archs=[LLAMA], autoscale=False)
    cpu_variants = [v for v in c.store.registry.variants.values()
                    if v.hardware == "cpu-host"]
    v = max(cpu_variants, key=lambda x: x.profile.peak_qps)
    w = next(iter(c.master.workers.values()))
    w.load_variant(v)
    c.run_until(10.0)
    rate = v.profile.peak_qps * 1.6   # beyond one replica
    poisson_arrivals(
        c.loop, lambda t: rate,
        lambda t: c.api.online_query(mod_var=v.name, latency_ms=10_000),
        t_end=40.0, seed=1)
    c.run_until(30.0)   # mid-load: replicas grew
    li = w.instances.get(v.name)
    assert li is not None and li.replicas >= 2, li.replicas
    c.run_until(120.0)  # load gone: hysteretic scale-down kicks in
    li = w.instances.get(v.name)
    assert li is None or li.replicas < 4


def test_worker_autoscaler_upgrades_accel_variant():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    accel_b1 = [v for v in c.store.registry.variants.values()
                if v.hardware == "tpu-v5e-1" and v.batch_opt == 1
                and "bf16" in v.framework][0]
    w = next(iter(c.master.workers.values()))
    w.load_variant(accel_b1)
    c.run_until(10.0)
    rate = accel_b1.profile.peak_qps * 2.5
    poisson_arrivals(
        c.loop, lambda t: rate,
        lambda t: c.api.online_query(mod_arch=LLAMA.name, latency_ms=10_000),
        t_end=60.0, seed=2)
    c.run_until(90.0)
    batches = [li.variant.batch_opt for li in w.instances.values()
               if li.variant.is_accel]
    assert batches and max(batches) > 1, batches


def test_scale_down_is_hysteretic():
    c = make_cluster(n_accel=0, n_cpu=1, archs=[LLAMA], autoscale=False)
    v = max((x for x in c.store.registry.variants.values()
             if x.hardware == "cpu-host"), key=lambda x: x.profile.peak_qps)
    w = next(iter(c.master.workers.values()))
    w.load_variant(v, replicas=3)
    c.run_until(5.0)
    li = w.instances[v.name]
    assert li.replicas == 3
    # zero load: must NOT scale down before T_cpu=10 autoscale ticks
    c.run_until(5.0 + 5.0)
    assert w.instances[v.name].replicas == 3
    c.run_until(5.0 + 30.0)
    assert w.instances[v.name].replicas < 3


def test_offline_best_effort_and_throttling():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    job = c.api.offline_query(mod_arch=LLAMA.name, n_inputs=2000)
    c.run_until(120.0)
    assert job.processed > 0, "offline job made no progress in slack"
    # online queries co-located with offline still meet relaxed SLOs
    qs = [c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000)
          for _ in range(16)]
    c.run_until(240.0)
    assert all(_done(q) for q in qs)
    online_viol = sum(q.violated for q in qs)
    assert online_viol <= 2, online_viol


def test_worker_failure_redispatch():
    cfg = MasterConfig()
    c = make_cluster(n_accel=2, archs=[LLAMA], autoscale=False, cfg=cfg)
    c.api.online_query(mod_arch=LLAMA.name, latency_ms=10_000)
    c.run_until(30.0)
    # saturate both workers then kill one
    qs = [c.api.online_query(mod_arch=LLAMA.name, latency_ms=60_000)
          for _ in range(32)]
    victims = [n for n, w in c.master.workers.items()
               if any(li.pending or li.outstanding
                      for li in w.instances.values())]
    assert victims
    c.master.fail_worker(victims[0])
    c.run_until(240.0)
    done = [q for q in qs if _done(q)]
    assert len(done) == len(qs), f"{len(done)}/{len(qs)} after failure"
    # dead worker is out of the routing tables
    assert not c.store.workers[victims[0]].alive


def test_hedged_requests_cut_straggler_latency():
    cfg = MasterConfig(hedge_enabled=True, hedge_factor=2.0)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg)
    c.master.add_worker("accel", name="straggler", slowdown=25.0)
    # preload the same variant on both workers
    v = [x for x in c.store.registry.variants.values()
         if x.hardware == "tpu-v5e-1" and x.batch_opt == 8
         and "bf16" in x.framework][0]
    for w in c.master.workers.values():
        w.load_variant(v)
    c.run_until(60.0)
    # route a query to the straggler explicitly
    q = c.master.online_query(n_inputs=1, slo=30.0, variant=v.name)
    c.run_until(300.0)
    assert _done(q)
    slow_latency = v.profile.latency(1) * 25.0
    assert q.latency < slow_latency, (q.latency, slow_latency)


def test_master_autoscaler_adds_and_removes_workers():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=True)
    v = [x for x in c.store.registry.variants.values()
         if x.hardware == "tpu-v5e-1" and x.batch_opt == 8
         and "bf16" in x.framework][0]
    rate = v.profile.peak_qps * 1.5
    poisson_arrivals(
        c.loop, lambda t: rate,
        lambda t: c.api.online_query(mod_arch=LLAMA.name, latency_ms=2000),
        t_end=45.0, seed=3)
    c.run_until(60.0)
    n_peak = sum(1 for w in c.store.workers.values() if w.alive)
    assert n_peak > 1, "master autoscaler never scaled out"
    # cool-down: idle variants unload, then idle workers retire
    c.run_until(300.0)
    n_end = sum(1 for w in c.store.workers.values() if w.alive)
    assert n_end < n_peak


def test_metadata_heartbeat_failure_detection():
    c = make_cluster(n_accel=2, archs=[LLAMA], autoscale=False)
    c.run_until(10.0)
    name = next(iter(c.master.workers))
    # silence heartbeats without the master's fail_worker shortcut
    c.master.workers[name].alive = False
    c.run_until(30.0)
    assert not c.store.workers[name].alive, \
        "missed heartbeats did not mark the worker dead"
