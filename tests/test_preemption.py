"""Graceful degradation under KV memory pressure (ISSUE 6 acceptance).

Pins the tentpole's guarantees:

* bit-identical recovery — a preempted request (pages freed, parked
  host-side, prefix replayed through the chunked-prefill seat) finishes
  with exactly the tokens an uninterrupted run produces, for dense + ssm
  + hybrid, on the paged and (where applicable) contiguous layouts;
* mid-flight preemption — preempting during a chunked prefill (before
  the first token ever emitted) and under the in-segment staging ring
  both recover exactly;
* optimistic > worst-case — on a pool sized at half the aggregate
  worst-case demand, optimistic admission reaches strictly higher peak
  concurrency than worst-case admission and still matches the
  uncontended reference token-for-token (the ISSUE headline);
* page hygiene — preempt/re-admit cycles leak nothing: a full drain
  returns every page and zeroes every reservation;
* allocator invariants under optimistic interleavings — a seeded fuzz
  (no hypothesis dependency; runs in the fast CI job) drives
  reserve(strict=False)/cover/release/rekey schedules and checks no
  double-held pages and exact free accounting;
* control-plane surfacing — ``EngineExecutor`` logs preemption /
  pressure-stall counts per run and reports the degraded verdict
  through ``ExecRequest.on_report``.
"""
import numpy as np
import pytest

import jax

from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.serving.engine import PageAllocator, Request, ServingEngine

_BUILT = {}


def _build(arch):
    if arch not in _BUILT:
        cfg = ARCHS[arch].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _BUILT[arch] = (cfg, model, params)
    return _BUILT[arch]


def _stream(cfg, n=6, seed=11, max_new=(4, 9)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(3, 10))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def _assert_match(ref, got, msg=""):
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(
            np.asarray(a.tokens), np.asarray(b.tokens),
            err_msg=f"{msg} rid={a.rid}")


# ---------------------------------------------------------------------
# engine-level recovery (real models: slow, full tier-1 covers them)

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
@pytest.mark.parametrize("page_size", [None, 8])
def test_forced_preempt_recovers_bit_identical(arch, page_size):
    """Preempt a live slot mid-decode; the parked request replays its
    prefix (prompt + tokens already generated) and finishes with exactly
    the uninterrupted run's tokens. xLSTM has no attention KV to page —
    the paged knob is inert there, and preemption recovers through the
    same empty-state teacher-forcing seam."""
    cfg, model, params = _build(arch)
    kw = dict(max_batch=2, max_len=64, decode_block=4, min_bucket=4)
    if page_size is not None:
        kw["page_size"] = page_size
    ref_engine = ServingEngine(model, params, **kw)
    ref = _stream(cfg)
    ref_engine.serve(ref)

    eng = ServingEngine(model, params, **kw)
    got = _stream(cfg)
    for r in got:
        eng.submit(r)
    eng.step()                       # victims have decoded some tokens
    live = [s for s in range(eng.max_batch)
            if eng._slot_req[s] is not None]
    assert live
    eng.preempt(live[0])
    while eng.busy:
        eng.step()
    eng.drain_completions()
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["preempt_readmits"] >= 1
    assert any(r.preemptions >= 1 for r in got)
    _assert_match(ref, got, f"{arch} ps={page_size}:")
    if eng._paged:
        assert eng._alloc.n_free == eng.n_pages
        assert eng._alloc.committed == 0


@pytest.mark.slow
def test_preempt_mid_chunked_prefill_recovers():
    """Preempting a slot that is still teacher-forcing its prompt (no
    token emitted yet) parks a pure-prompt prefix; recovery restarts the
    chunked prefill from scratch and matches exactly."""
    cfg, model, params = _build("llama3.2-1b")
    kw = dict(max_batch=1, max_len=64, decode_block=4, min_bucket=4,
              page_size=8, chunk_threshold=8)
    long_prompt = (np.arange(20, dtype=np.int32) * 3 + 1) % cfg.vocab

    ref_engine = ServingEngine(model, params, **kw)
    ref = Request(rid=0, prompt=long_prompt.copy(), max_new_tokens=5)
    ref_engine.serve([ref])
    assert ref_engine.stats["chunk_admits"] == 1

    eng = ServingEngine(model, params, **kw)
    got = Request(rid=0, prompt=long_prompt.copy(), max_new_tokens=5)
    eng.submit(got)
    eng.step()                       # one 4-position chunk: mid-prefill
    assert got.tokens is None
    eng.preempt(0)
    assert eng._preempted and len(eng._preempted[0].done) == 0
    while eng.busy:
        eng.step()
    eng.drain_completions()
    assert got.preemptions == 1
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(got.tokens))
    assert eng._alloc.n_free == eng.n_pages


@pytest.mark.slow
def test_optimistic_beats_worstcase_concurrency_bit_identical():
    """The ISSUE headline, pinned: on a pool at ~50% of aggregate
    worst-case demand, optimistic admission serves strictly more
    concurrent requests than worst-case admission, completes the whole
    stream, and every output matches the uncontended big-pool
    reference."""
    cfg, model, params = _build("llama3.2-1b")
    kw = dict(max_batch=4, max_len=64, decode_block=8, min_bucket=4,
              page_size=8)
    # prompts 3..9 + max_new up to 12 -> worst case 3 pages; 4 slots
    # want 12 pages, the pressure pool grants 6
    ref_engine = ServingEngine(model, params, n_pages=12, **kw)
    ref = _stream(cfg, n=10, max_new=(6, 13))
    ref_engine.serve(ref)

    wc = ServingEngine(model, params, n_pages=6,
                       admission="worstcase", **kw)
    got_wc = _stream(cfg, n=10, max_new=(6, 13))
    wc.serve(got_wc)
    _assert_match(ref, got_wc, "worstcase:")

    opt = ServingEngine(model, params, n_pages=6,
                        admission="optimistic", **kw)
    got = _stream(cfg, n=10, max_new=(6, 13))
    opt.serve(got)
    _assert_match(ref, got, "optimistic:")
    assert opt.stats["peak_concurrency"] > wc.stats["peak_concurrency"]
    assert opt.stats["preemptions"] > 0
    assert opt.stats["pressure_stalls"] > 0
    assert opt.stats["preempt_readmits"] == opt.stats["preemptions"]
    assert opt._alloc.n_free == opt.n_pages
    assert opt._alloc.committed == 0


@pytest.mark.slow
def test_optimistic_pressure_with_staging_ring():
    """Pressure relief prefers un-staging (zero work lost) before
    preempting live slots, and the in-segment refill path stays exact
    under an over-committed pool."""
    cfg, model, params = _build("llama3.2-1b")
    kw = dict(max_batch=2, max_len=64, decode_block=8, min_bucket=4,
              page_size=8)
    ref_engine = ServingEngine(model, params, **kw)
    ref = _stream(cfg, n=8, max_new=(6, 13))
    ref_engine.serve(ref)

    eng = ServingEngine(model, params, n_pages=4, stage_slots=2,
                        admission="optimistic", **kw)
    got = _stream(cfg, n=8, max_new=(6, 13))
    eng.serve(got)
    _assert_match(ref, got, "staged+optimistic:")
    assert eng.stats["pressure_stalls"] > 0
    assert eng._alloc.n_free == eng.n_pages
    assert eng._alloc.committed == 0
    assert len(eng._staged) == 0 and not eng._preempted


@pytest.mark.slow
def test_slack_policy_protects_tight_slo():
    """With one no-SLO request and one tight-SLO request live, pressure
    preempts the no-SLO one (infinite slack)."""
    cfg, model, params = _build("llama3.2-1b")
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=8, min_bucket=4, page_size=8)
    loose = Request(rid=0, prompt=np.arange(4, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=6, slo=None)
    tight = Request(rid=1, prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                    max_new_tokens=6, slo=0.001)
    eng.submit(loose)
    eng.submit(tight)
    eng._admit_pending()
    slots = {eng._slot_req[s].rid: s for s in range(2)
             if eng._slot_req[s] is not None}
    v = eng._pick_victim(exclude=-1)
    assert v == slots[0], "slack policy must pick the no-SLO request"
    # lru picks the most recently admitted instead
    eng.preempt_policy = "lru"
    assert eng._pick_victim(exclude=-1) == slots[1]


@pytest.mark.slow
def test_admission_knob_validation():
    cfg, model, params = _build("llama3.2-1b")
    with pytest.raises(ValueError):
        ServingEngine(model, params, admission="hopeful")
    with pytest.raises(ValueError):
        ServingEngine(model, params, preempt_policy="random")
    # optimistic admission needs a paged pool + a replay path: clamped to
    # worst-case on the contiguous layout...
    eng = ServingEngine(model, params, admission="optimistic")
    assert eng.admission == "worstcase"
    # ...and for families with no teacher-forcing seam
    _, moe_model, moe_params = _build("moonshot-v1-16b-a3b")
    eng = ServingEngine(moe_model, moe_params, page_size=8,
                        admission="optimistic")
    assert eng.admission == "worstcase"
    with pytest.raises(ValueError):
        eng.preempt(0)               # no replay path -> no preemption


# ---------------------------------------------------------------------
# allocator invariants under optimistic interleavings (fast: no models)

def _alloc_invariants(alloc, parked_ok=False):
    live = alloc.live_pages()
    assert len(live) == len(set(live)), "page double-held"
    assert len(live) + alloc.n_free == alloc.n_pages
    for holder, pages in alloc._pages.items():
        assert len(pages) <= alloc._reserved[holder]
    if not parked_ok:
        assert alloc.committed <= alloc.n_pages


def test_allocator_optimistic_fuzz_preempt_readmit():
    """Seeded fuzz (no hypothesis needed): random interleavings of
    optimistic reserve / cover / preempt-release / re-admit keep the
    pool exact — no page ever double-held, free + held == n_pages at
    every step, and a full drain returns everything."""
    rng = np.random.default_rng(0)
    for trial in range(40):
        n_pages = int(rng.integers(2, 12))
        page = int(rng.integers(1, 5))
        alloc = PageAllocator(n_pages, page)
        live = {}                    # holder -> worst-case positions
        parked = []                  # preempted holders awaiting re-admit
        nxt = 0
        for _ in range(60):
            op = rng.integers(4)
            if op == 0:              # optimistic admit (over-commit ok)
                npos = int(rng.integers(1, n_pages * page + 1))
                alloc.reserve(("h", nxt), npos, strict=False)
                live[("h", nxt)] = npos
                nxt += 1
            elif op == 1 and live:   # grow within free pages
                h = list(live)[int(rng.integers(len(live)))]
                npos = int(rng.integers(1, live[h] + 1))
                if alloc.can_cover(h, npos):
                    alloc.cover(h, npos)
            elif op == 2 and live:   # preempt: release, park
                h = list(live)[int(rng.integers(len(live)))]
                alloc.release(h)
                parked.append((h, live.pop(h)))
            elif op == 3 and parked:  # re-admit a parked holder
                h, npos = parked.pop(0)
                if alloc.pages_needed(npos) <= alloc.n_free:
                    alloc.reserve(h, npos, strict=False)
                    alloc.cover(h, min(npos, page))
                    live[h] = npos
                else:
                    parked.insert(0, (h, npos))
            _alloc_invariants(alloc, parked_ok=True)
        for h in list(live):
            alloc.release(h)
        assert alloc.n_free == alloc.n_pages, f"trial {trial} leaked"
        assert alloc.committed == 0


def test_allocator_strict_reserve_still_refuses_overcommit():
    """strict=True (worst-case admission) keeps the hard guarantee:
    reservations can never exceed the pool."""
    alloc = PageAllocator(4, 8)
    alloc.reserve("a", 32)           # exactly the pool
    with pytest.raises(ValueError):
        alloc.reserve("b", 1)
    alloc.reserve("c", 8, strict=False)   # optimistic over-commit is fine
    assert alloc.committed == 5
    alloc.release("a")
    alloc.release("c")
    assert alloc.n_free == 4 and alloc.committed == 0


# ---------------------------------------------------------------------
# control-plane surfacing

@pytest.mark.slow
def test_executor_surfaces_preemptions_and_degraded():
    """EngineExecutor under a starved optimistic pool: the occupancy log
    carries preemption / pressure-stall counts and on_report delivers
    the degraded verdict for the query whose work was preempted."""
    from repro.core import profiler as prof
    from repro.core.worker import ExecRequest
    from repro.serving.executor import EngineExecutor, EngineExecutorConfig
    acfg = ARCHS["llama3.2-1b"]
    variants = prof.generate_variants(acfg)
    v = next(x for x in variants if x.hardware == "cpu-host")
    ex = EngineExecutor(
        {acfg.name: acfg.reduced()},
        EngineExecutorConfig(max_batch=4, max_len=64, decode_block=8,
                             min_bucket=4, page_size=8, n_pages=6,
                             admission="optimistic"))
    reports = []
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, acfg.reduced().vocab, size=int(p))
               .astype(np.int32) for p in rng.integers(4, 10, size=8)]
    er = ExecRequest(n_inputs=len(prompts), prompts=tuple(prompts),
                     max_new_tokens=10, slo=5.0,
                     on_outputs=lambda outs: None,
                     on_report=reports.append)
    ex.run(v, batch=len(prompts), requests=[er])
    eng = ex.engines[v.name]
    assert eng.admission == "optimistic"
    rec = ex.occupancy_log[-1]
    assert rec["preemptions"] == eng.stats["preemptions"]
    assert rec["pressure_stalls"] == eng.stats["pressure_stalls"]
    assert reports and reports[0]["preemptions"] >= 1
    assert reports[0]["degraded"] is True
