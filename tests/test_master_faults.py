"""Fault-injection hardening for the control plane (ISSUE 6 satellites).

* A worker that silently stops heartbeating mid-job (``Worker.hang()`` —
  alive but frozen, completions never fire) must not strand its queries:
  the master's heartbeat sweep routes the timeout through
  ``Worker.fail()``, so pending *and in-flight* work fails through
  ``done_cb`` into the retry machinery and finishes elsewhere.
* Retries back off exponentially with jitter
  (``retry_delay * retry_backoff**k``, capped at ``retry_delay_cap``)
  instead of hammering a fixed period, and every dispatch stamps the
  attempt count the ``QueryResult`` surfaces.
* Transient failures recover (attempts > 1, query completes); permanent
  failures exhaust the budget (``max_retries + 1`` attempts) over at
  least the sum of the backoff schedule.
"""
from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.core.master import MasterConfig
from repro.sim.cluster import make_cluster

LLAMA = ARCHS["llama3.2-1b"]


def _done(q):
    return q.finish >= 0 and not q.failed


def test_hung_worker_queries_redispatch_and_complete():
    """Regression: a heartbeat-silent (hung, not failed) worker's pending
    and in-flight queries used to strand forever — the sweep marked the
    worker dead in the store but never failed its queries, and a hung
    worker's scheduled completions never fire. They must re-dispatch and
    complete."""
    c = make_cluster(n_accel=2, archs=[LLAMA], autoscale=False)
    c.api.online_query(mod_arch=LLAMA.name, latency_ms=10_000)
    c.run_until(30.0)
    qs = [c.api.online_query(mod_arch=LLAMA.name, latency_ms=60_000)
          for _ in range(32)]
    victims = [n for n, w in c.master.workers.items()
               if any(li.pending or li.outstanding
                      for li in w.instances.values())]
    assert victims
    c.master.workers[victims[0]].hang()      # silent: no fail_worker call
    c.run_until(240.0)
    done = [q for q in qs if _done(q)]
    assert len(done) == len(qs), \
        f"{len(done)}/{len(qs)} completed after silent hang"
    assert not c.store.workers[victims[0]].alive, \
        "heartbeat sweep never detected the hung worker"
    # the stranded queries went around the retry loop at least once
    assert max(q.attempts for q in qs) > 1
    assert all(q.attempts >= 1 for q in qs)


def test_transient_failure_recovers_with_attempt_count():
    """An explicit worker failure is transient cluster-wide: the other
    worker absorbs the re-dispatches, and the retried queries carry
    attempts > 1 all the way into the public QueryResult."""
    c = make_cluster(n_accel=2, archs=[LLAMA], autoscale=False)
    c.api.online_query(mod_arch=LLAMA.name, latency_ms=10_000)
    c.run_until(30.0)
    hs = [c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=60_000))
          for _ in range(16)]
    victims = [n for n, w in c.master.workers.items()
               if any(li.pending or li.outstanding
                      for li in w.instances.values())]
    assert victims
    c.master.fail_worker(victims[0])
    c.run_until(240.0)
    results = [h.result(timeout=1.0) for h in hs]
    assert all(r.ok for r in results)
    assert max(r.attempts for r in results) > 1
    assert all(r.attempts >= 1 for r in results)


def test_permanent_failure_exhausts_backoff_budget():
    """With every worker dead, a query burns its full retry budget —
    max_retries + 1 attempts — spread over at least the deterministic
    part of the exponential backoff schedule, then fails for good."""
    cfg = MasterConfig()
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg)
    c.run_until(10.0)
    for name in list(c.master.workers):
        c.master.fail_worker(name)
    t0 = c.loop.now()
    q = c.api.online_query(mod_arch=LLAMA.name, latency_ms=5000)
    c.run_until(t0 + 120.0)
    assert q.failed
    assert q.attempts == cfg.max_retries + 1
    # sum of min(delay * backoff**k, cap) for k = 0..max_retries-1,
    # jitter can shave at most retry_jitter off each wait
    sched = sum(min(cfg.retry_delay * cfg.retry_backoff ** k,
                    cfg.retry_delay_cap) for k in range(cfg.max_retries))
    assert q.finish - t0 >= sched * (1.0 - cfg.retry_jitter), \
        (q.finish - t0, sched)
    assert q.finish - t0 <= sched * (1.0 + cfg.retry_jitter) + 1.0


def test_backoff_delays_grow_and_cap():
    """The per-retry delay schedule is exponential, capped, and jittered
    within +/- retry_jitter."""
    cfg = MasterConfig(retry_delay=0.1, retry_backoff=2.0,
                       retry_delay_cap=0.5, retry_jitter=0.1)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg)
    m = c.master
    for k, base in enumerate([0.1, 0.2, 0.4, 0.5, 0.5, 0.5]):
        for _ in range(3):
            d = m._retry_delay_for(k)
            assert base * 0.9 <= d <= base * 1.1, (k, d, base)
    # jitter desynchronizes retries: not every draw is identical
    draws = {round(m._retry_delay_for(3), 6) for _ in range(16)}
    assert len(draws) > 1


def test_hung_worker_offline_job_not_stranded():
    """Offline jobs on a hung worker fail through the abandon path and
    re-enter the master's offline retry loop once the sweep fires."""
    c = make_cluster(n_accel=2, archs=[LLAMA], autoscale=False)
    c.run_until(10.0)
    h = c.api.submit(QuerySpec.arch(LLAMA.name, mode="offline",
                                    n_inputs=64))
    c.run_until(12.0)
    hosts = [n for n, w in c.master.workers.items() if w.offline_jobs]
    if hosts:                       # job already placed: hang its host
        c.master.workers[hosts[0]].hang()
    c.run_until(400.0)
    r = h.result(timeout=1.0)
    assert r.ok, "offline job stranded on hung worker"
    assert r.attempts >= 1
