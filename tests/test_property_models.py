"""Property tests on model-math invariants: the chunkwise-parallel forms of
Mamba2 SSD and mLSTM must match their step-by-step recurrences exactly."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked, ssd_step
from repro.models.xlstm import mlstm_chunked, mlstm_recurrent


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(16, 4), (32, 8), (64, 16), (64, 64)]),
       st.integers(1, 3), st.integers(1, 3))
def test_ssd_chunked_matches_recurrence(seed, l_chunk, b, h):
    L, chunk = l_chunk
    n, p = 8, 4
    rng = np.random.default_rng(seed)
    xdt = jnp.asarray(rng.normal(size=(b, L, h, p)), jnp.float32)
    dA = jnp.asarray(-np.abs(rng.normal(size=(b, L, h))) * 0.1, jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(b, L, n)), jnp.float32)
    C_ = jnp.asarray(rng.normal(size=(b, L, n)), jnp.float32)

    y_chunk, state_chunk = ssd_chunked(xdt, dA, B_, C_, chunk)

    state = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(L):
        y, state = ssd_step(xdt[:, t], dA[:, t], B_[:, t], C_[:, t], state)
        ys.append(y)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(16, 4), (32, 8), (64, 16), (32, 32)]),
       st.integers(1, 2), st.integers(1, 2))
def test_mlstm_chunked_matches_recurrent(seed, s_chunk, b, h):
    S, chunk = s_chunk
    d = 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, S, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, S, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, S, h, d)), jnp.float32)
    log_i = jnp.asarray(rng.normal(size=(b, S, h)), jnp.float32)
    log_f = jnp.asarray(-np.abs(rng.normal(size=(b, S, h))) - 0.05,
                        jnp.float32)

    h_chunk, (C1, n1, m1) = mlstm_chunked(q, k, v, log_i, log_f, chunk)
    h_rec, (C2, n2, m2) = mlstm_recurrent(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_rec),
                               rtol=2e-4, atol=2e-4)
    # carried state is stabilizer-normalized; compare in true space
    np.testing.assert_allclose(
        np.asarray(C1 * jnp.exp(m1)[..., None, None]),
        np.asarray(C2 * jnp.exp(m2)[..., None, None]), rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_mlstm_state_carry_across_calls(seed):
    """Splitting a sequence across two chunked calls == one call."""
    b, S, h, d, chunk = 1, 32, 2, 8, 8
    rng = np.random.default_rng(seed)
    def mk(*shape):
        return jnp.asarray(rng.normal(size=shape), jnp.float32)
    q, k, v = mk(b, S, h, d), mk(b, S, h, d), mk(b, S, h, d)
    li = mk(b, S, h)
    lf = jnp.asarray(-np.abs(rng.normal(size=(b, S, h))) - 0.05, jnp.float32)
    full, _ = mlstm_chunked(q, k, v, li, lf, chunk)
    h1, st1 = mlstm_chunked(q[:, :16], k[:, :16], v[:, :16],
                            li[:, :16], lf[:, :16], chunk)
    h2, _ = mlstm_chunked(q[:, 16:], k[:, 16:], v[:, 16:],
                          li[:, 16:], lf[:, 16:], chunk, state=st1)
    got = jnp.concatenate([h1, h2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_ssd_state_carry_across_calls(seed):
    b, L, h, n, p, chunk = 1, 32, 2, 4, 4, 8
    rng = np.random.default_rng(seed)
    xdt = jnp.asarray(rng.normal(size=(b, L, h, p)), jnp.float32)
    dA = jnp.asarray(-np.abs(rng.normal(size=(b, L, h))) * 0.1, jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(b, L, n)), jnp.float32)
    C_ = jnp.asarray(rng.normal(size=(b, L, n)), jnp.float32)
    full, _ = ssd_chunked(xdt, dA, B_, C_, chunk)
    y1, st1 = ssd_chunked(xdt[:, :16], dA[:, :16], B_[:, :16], C_[:, :16],
                          chunk)
    y2, _ = ssd_chunked(xdt[:, 16:], dA[:, 16:], B_[:, 16:], C_[:, 16:],
                        chunk, h0=st1)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
