"""Property-based tests (hypothesis) on control-plane invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import ARCHS
from repro.core import profiler as prof
from repro.core.metadata import MetadataStore
from repro.core.selection import VariantSelector
from repro.sim.clock import EventLoop
from repro.sim.cluster import make_cluster
from repro.sim.workload import popularity_split, poisson_arrivals, zipf_weights


@given(st.lists(st.floats(0, 100), min_size=1, max_size=50),
       st.integers(0, 2**31 - 1))
def test_eventloop_fires_in_time_order(delays, seed):
    loop = EventLoop()
    fired = []
    for i, d in enumerate(delays):
        loop.schedule(d, (lambda ii, dd: lambda: fired.append((loop.now())))(
            i, d))
    loop.run_until(1e9)
    assert fired == sorted(fired)
    assert len(fired) == len(delays)


@given(st.floats(1e-6, 10), st.floats(0, 10))
def test_fit_linear_recovers_exact_line(m, c):
    batches = [1, 4, 8]
    lats = [m * b + c for b in batches]
    m2, c2 = prof.fit_linear(batches, lats)
    np.testing.assert_allclose([m2, c2], [max(m, 1e-9), max(c, 1e-6)],
                               rtol=1e-6, atol=1e-6)


@given(st.integers(2, 40), st.floats(0.5, 2.0))
def test_zipf_weights_normalized_and_monotone(n, alpha):
    w = zipf_weights(n, alpha)
    assert abs(w.sum() - 1.0) < 1e-9
    assert all(w[i] >= w[i + 1] for i in range(n - 1))


@given(st.integers(2, 10))
def test_popularity_split_80_20(n):
    archs = [f"arch{i}" for i in range(n)]
    split = popularity_split(archs)
    total = sum(split.weights.values())
    assert abs(total - 1.0) < 1e-9
    pop_mass = sum(split.weights[a] for a in split.popular)
    if split.cold:
        assert abs(pop_mass - 0.8) < 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.one_of(st.none(), st.floats(1e-3, 10.0)))
def test_selection_respects_batch_and_slo(batch, slo):
    store = MetadataStore()
    prof.register_all(store.registry, [ARCHS["llama3.2-1b"]])
    store.upsert_worker("w0", ("cpu-host", "tpu-v5e-1"), 0.0)
    store.heartbeat("w0", {"cpu-host": 0.1, "tpu-v5e-1": 0.1},
                    {"cpu-host": 0.0, "tpu-v5e-1": 0.0}, 0.0)
    sel = VariantSelector(store)
    r = sel.select_arch("llama3.2-1b", batch, slo)
    if r.variant is not None and r.reason != "slo-relaxed":
        assert batch <= r.variant.profile.max_batch
        if slo is not None:
            assert r.variant.profile.latency(batch) <= slo + 1e-9


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(20.0, 300.0))
def test_sim_invariants_under_random_load(seed, rate):
    """Random Poisson load: memory accounting, replica caps, and query
    timestamps stay consistent throughout."""
    c = make_cluster(n_accel=1, n_cpu=1, archs=[ARCHS["llama3.2-1b"]],
                     autoscale=False)
    poisson_arrivals(
        c.loop, lambda t: rate,
        lambda t: c.api.online_query(mod_arch="llama3.2-1b",
                                     latency_ms=5000),
        t_end=20.0, seed=seed)
    c.run_until(40.0)
    for w in c.master.workers.values():
        for hname, dev in w.devices.items():
            assert dev.mem_used <= dev.hw.mem_capacity + 1e-6
            assert dev.active >= 0
        cpu = w.devices.get("cpu-host")
        if cpu is not None:
            used = sum(li.replicas for li in w.instances.values()
                       if not li.variant.is_accel)
            assert used <= cpu.slots
    for q in c.master.metrics:
        if q.finish >= 0 and not q.failed:
            assert q.arrival <= q.start <= q.finish
            v = c.store.registry.variants[q.variant]
            assert q.n_inputs <= v.profile.max_batch
