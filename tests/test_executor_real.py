"""The Executor seam: control plane over real engines (ISSUE 2 tentpole),
and the payload path (ISSUE 3): a QuerySpec carrying real prompts served
through master -> worker -> EngineExecutor -> ServingEngine, generated
tokens returned via QueryHandle.result().

``make_cluster(backend="real")`` serves a mixed stream through
master -> variant selection -> ``EngineExecutor`` (real continuous-batching
engines on reduced configs), and measured service times re-fit variant
profiles in place — the closed loop between data plane and control plane.
"""
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.api import QueryPayload, QuerySpec
from repro.core.master import MasterConfig
from repro.core.worker import Executor, SimExecutor
from repro.sim.cluster import make_cluster

LLAMA = ARCHS["llama3.2-1b"]

# tests that build real JAX models are excluded from the fast CI job
slow = pytest.mark.slow


def _done(q):
    return q.finish >= 0 and not q.failed


def test_sim_executor_is_the_default_and_satisfies_protocol():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    w = next(iter(c.master.workers.values()))
    assert isinstance(w.executor, SimExecutor)
    assert isinstance(w.executor, Executor)
    v = next(iter(c.store.registry.variants.values()))
    assert w.executor.run(v, 4) == pytest.approx(v.profile.latency(4))


@slow
def test_real_backend_serves_and_calibrates_profiles():
    """End-to-end acceptance: a mixed stream runs through selection into
    real engines, and at least one variant's m/c is re-fit from measured
    service times."""
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    before = {v.name: (v.profile.m, v.profile.c)
              for v in c.store.registry.variants.values()}
    assert all(v.profile.source == "analytic"
               for v in c.store.registry.variants.values())
    # one early query (a batch-1 job), then a burst that the worker's
    # adaptive batching packs into a larger job -> two distinct batch
    # sizes observed -> refit
    qs = [c.api.online_query(mod_arch=LLAMA.name, latency_ms=600_000)]
    c.run_until(30.0)
    qs += [c.api.online_query(mod_arch=LLAMA.name, latency_ms=600_000)
           for _ in range(7)]
    c.run_until(300.0)
    assert all(_done(q) for q in qs), \
        [(q.qid, q.failed, q.finish) for q in qs]

    w = next(iter(c.master.workers.values()))
    ex = w.executor
    assert ex.engines, "no real engine was ever built"
    # real engines actually decoded tokens for every job
    assert sum(e.stats["tokens_generated"]
               for e in ex.engines.values()) > 0
    batches = {b for obs in ex.observations.values() for b in obs}
    assert len(batches) >= 2, batches

    measured = [v for v in c.store.registry.variants.values()
                if v.profile.source == "measured"]
    assert measured, "no profile was re-fit from measurements"
    for v in measured:
        assert (v.profile.m, v.profile.c) != before[v.name]
        assert v.profile.latency(1) > 0
        # peak_qps was recomputed against the measured fit
        assert v.profile.peak_qps == pytest.approx(
            v.profile.max_batch / v.profile.latency(v.profile.max_batch))


@slow
def test_real_backend_queries_see_measured_latency():
    """Virtual-clock query latency reflects real measured service time,
    not the analytic roofline guess."""
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    q = c.api.online_query(mod_arch=LLAMA.name, latency_ms=600_000)
    c.run_until(60.0)
    assert _done(q)
    w = next(iter(c.master.workers.values()))
    obs = [t for per_b in w.executor.observations.values()
           for ts in per_b.values() for t in ts]
    assert obs
    # service portion of the query latency equals a measured duration
    assert q.finish - q.start == pytest.approx(obs[0])


def test_usecase_query_redispatch_reselects():
    """Regression (ISSUE 2 satellite): a use-case query that cannot be
    placed yet must retry via select_usecase — not fail because it carries
    neither arch nor variant."""
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    q = c.api.online_query(task="text-generation", dataset="openwebtext",
                           accuracy=0.5, latency_ms=600_000)
    assert q.task == "text-generation" and q.dataset == "openwebtext"
    # capacity appears only after the query has started retrying
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    c.run_until(120.0)
    assert _done(q), (q.failed, q.finish)
    assert q.variant


def test_variant_query_redispatch_reselects():
    """Same hole as above for variant-named queries: the user's mod_var
    choice must survive a failed first dispatch and retry."""
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    vname = next(v.name for v in c.store.registry.variants.values()
                 if v.hardware == "tpu-v5e-1")
    q = c.api.online_query(mod_var=vname, latency_ms=600_000)
    assert q.variant == vname
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    c.run_until(120.0)
    assert _done(q), (q.failed, q.finish)
    assert q.variant == vname


def test_variant_objects_stay_hashable():
    """The frozen Variant hashes its (identity-hashed, mutable) profile;
    sets/dict keys of Variants must keep working."""
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    vs = list(c.store.registry.variants.values())
    assert len({v for v in vs}) == len(vs)
    assert vs[0] in {vs[0]}


@slow
def test_jax_executor_measured_keyed_by_prompt_len():
    """Regression (ISSUE 2 satellite): mixed-length calibration runs must
    not overwrite each other."""
    from repro.serving.engine import JaxExecutor
    ex = JaxExecutor({LLAMA.name: LLAMA.reduced()},
                     max_batch=2, max_len=32, decode_block=4, min_bucket=4)
    ex.execute(LLAMA.name, batch=2, prompt_len=4, max_new=2)
    ex.execute(LLAMA.name, batch=2, prompt_len=8, max_new=2)
    keys = set(ex.measured)
    assert keys == {(LLAMA.name, 2, 4), (LLAMA.name, 2, 8)}
    assert all(t > 0 for t in ex.measured.values())


# ----------------------------------------------------------------------
# ISSUE 3 acceptance: a real multi-prompt payload flows client -> master ->
# worker -> EngineExecutor -> ServingEngine and the generated token ids
# come back through QueryHandle.result(), bit-identical to driving the
# engine directly with the same prompts.
PROMPTS = ((3, 1, 4, 1, 5, 9), (2, 7, 1, 8), (1, 6, 1, 8, 0, 3, 3, 9))
MAX_NEW = 4


@slow
def test_real_payload_outputs_bit_identical_to_direct_engine():
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    spec = QuerySpec.usecase(
        "text-generation", "openwebtext", min_accuracy=0.5,
        latency_ms=600_000,
        payload=QueryPayload.of(PROMPTS, max_new_tokens=MAX_NEW))
    h = c.api.submit(spec)
    res = h.result(timeout=600.0)
    assert res.ok, (res.failed, res.variant)
    assert res.outputs is not None and len(res.outputs) == len(PROMPTS)
    for out in res.outputs:
        assert out.dtype == np.int32 and len(out) == MAX_NEW

    # drive a FRESH engine (same shared model/params, same geometry)
    # directly with the same prompts: outputs must match token for token
    w = next(iter(c.master.workers.values()))
    ex = w.executor
    variant = c.store.registry.variants[res.variant]
    exec_eng = ex.engines[variant.name]
    model, params = ex._model(variant.arch)
    from repro.serving.engine import Request, ServingEngine
    eng = ServingEngine(model, params, max_batch=exec_eng.max_batch,
                        max_len=exec_eng.max_len,
                        decode_block=exec_eng.decode_block,
                        min_bucket=exec_eng.min_bucket)
    reqs = [Request(rid=i, prompt=np.asarray(p, np.int32),
                    max_new_tokens=MAX_NEW)
            for i, p in enumerate(PROMPTS)]
    eng.serve(reqs)
    for r, out in zip(reqs, res.outputs):
        np.testing.assert_array_equal(r.tokens, out)


@slow
def test_real_offline_payload_produces_outputs():
    """Offline payloads are sliced chunk by chunk into the real engine and
    their outputs accumulate on the job in input order."""
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    prompts = tuple(tuple(int(x) for x in np.arange(2 + (i % 3)) + i)
                    for i in range(6))
    h = c.api.submit(QuerySpec.arch(
        LLAMA.name, mode="offline",
        payload=QueryPayload.of(prompts, max_new_tokens=2)))
    res = h.result(timeout=600.0)
    assert res.ok and res.processed >= len(prompts)
    assert len(h.job.outputs) == len(prompts)
    for out in h.job.outputs:
        assert len(out) == 2


def test_sim_backend_payload_is_accounted_not_executed():
    """On the sim backend a payload shapes n_inputs/batching but produces
    no outputs — the simulator has no tokens to return."""
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    h = c.api.submit(QuerySpec.arch(
        LLAMA.name, latency_ms=600_000,
        payload=QueryPayload.of(PROMPTS, max_new_tokens=MAX_NEW)))
    res = h.result(timeout=120.0)
    assert res.ok
    assert h.query.n_inputs == len(PROMPTS)
    assert res.outputs is None


@slow
def test_oversized_payload_fails_query_without_wedging_device():
    """A payload exceeding the real engine's max_len must fail the query
    (not leak a ValueError into the event loop) and leave the device
    usable for subsequent queries."""
    cfg = MasterConfig(worker_autoscale=False, max_retries=1,
                       retry_delay=0.1)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    bad = c.api.submit(QuerySpec.arch(
        LLAMA.name, latency_ms=600_000,
        payload=QueryPayload.of([list(range(40))], max_new_tokens=4)))
    res = bad.result(timeout=300.0)
    assert res.failed and not res.ok
    # the device slot was not leaked: a normal query still completes
    ok = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=600_000))
    assert ok.result(timeout=300.0).ok


@slow
def test_real_backend_without_payload_returns_no_outputs():
    """Synthetic stand-in prompts are accounting, not answers: a
    payload-less query on the real backend must not surface their
    decoded tokens as outputs."""
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    h = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=600_000))
    res = h.result(timeout=300.0)
    assert res.ok and res.outputs is None


@slow
def test_oversized_offline_payload_fails_once_not_forever():
    """A poisoned offline chunk must fail the job and leave the worker's
    offline queue — not be retried on every monitor tick."""
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    h = c.api.submit(QuerySpec.arch(
        LLAMA.name, mode="offline",
        payload=QueryPayload.of([list(range(40))], max_new_tokens=4)))
    res = h.result(timeout=300.0)
    assert res.failed and h.job.failed
    w = next(iter(c.master.workers.values()))
    assert h.job not in w.offline_jobs
    # and the cluster still serves normal traffic afterwards
    ok = c.api.submit(QuerySpec.arch(LLAMA.name, latency_ms=600_000))
    assert ok.result(timeout=300.0).ok


def test_payload_runs_do_not_refit_profiles():
    """Payload measurements have arbitrary prompt/decode shapes and must
    stay out of the synthetic t(b) calibration."""
    from repro.core import profiler as prof
    from repro.core.worker import ExecRequest
    from repro.serving.executor import EngineExecutor, EngineExecutorConfig

    class _NoRunEngine:
        busy = False
        stats = {"busy_slot_steps": 0, "bubble_slot_steps": 0,
                 "inseg_admissions": 0, "decode_dispatches": 0,
                 "preemptions": 0, "pressure_stalls": 0,
                 "prefix_hits": 0, "prefix_pages_reused": 0,
                 "cow_copies": 0, "evictions": 0}

        def warmup(self, prompt_lens=()):
            pass

        def submit(self, r):
            r.tokens = np.zeros(1, np.int32)

        def step(self):
            return 0

        def drain_completions(self):
            return []

    ex = EngineExecutor({LLAMA.name: LLAMA.reduced()},
                        EngineExecutorConfig())
    v = next(iter(prof.generate_variants(LLAMA)))
    ex.engines[v.name] = _NoRunEngine()
    ex.run(v, 2, [ExecRequest(n_inputs=2, prompts=((1, 2), (3,)),
                              max_new_tokens=1)])
    assert v.name not in ex.observations          # payload run: excluded
    ex.run(v, 2, [ExecRequest(n_inputs=2)])
    assert list(ex.observations[v.name]) == [2]   # synthetic run: recorded


@slow
def test_engine_executor_lru_eviction_caps_engines():
    """ISSUE 4 satellite: with ``max_engines`` set the per-variant engine
    map is an LRU — building past the cap evicts the least-recently-used
    engine, an evicted variant rebuilds lazily (and re-warms outside the
    measured window), and outputs stay correct after the round trip."""
    from repro.core import profiler as prof
    from repro.core.worker import ExecRequest
    from repro.serving.executor import EngineExecutor, EngineExecutorConfig

    ex = EngineExecutor({LLAMA.name: LLAMA.reduced()},
                        EngineExecutorConfig(max_engines=2, max_batch=2,
                                             max_len=16, decode_block=2,
                                             min_bucket=4, prompt_len=4,
                                             max_new=2))
    v1, v2, v3 = list(prof.generate_variants(LLAMA))[:3]
    ex.run(v1, 1)
    ex.run(v2, 1)
    assert set(ex.engines) == {v1.name, v2.name} and ex.evictions == 0
    ex.run(v3, 1)                       # v1 is the LRU victim
    assert set(ex.engines) == {v2.name, v3.name}
    assert ex.evictions == 1
    # touching v2 marks it most-recent: the next build evicts v3, not v2
    ex.run(v2, 1)
    ex.run(v1, 1)                       # lazy rebuild of the evictee
    assert set(ex.engines) == {v2.name, v1.name}
    assert ex.evictions == 2
    # rebuilt engine still serves real payloads correctly
    outs = []
    ex.run(v1, 1, [ExecRequest(n_inputs=1, prompts=((1, 2, 3),),
                               max_new_tokens=2,
                               on_outputs=outs.append)])
    assert len(outs) == 1 and len(outs[0][0]) == 2


@slow
def test_engine_executor_paged_knobs_reach_engines():
    """page_size / n_pages / chunk_threshold flow through the executor
    into every lazily-built engine."""
    from repro.core import profiler as prof
    from repro.serving.executor import EngineExecutor, EngineExecutorConfig

    ex = EngineExecutor({LLAMA.name: LLAMA.reduced()},
                        EngineExecutorConfig(max_batch=2, max_len=16,
                                             decode_block=2, min_bucket=4,
                                             prompt_len=4, max_new=2,
                                             page_size=8,
                                             chunk_threshold=8))
    v = next(iter(prof.generate_variants(LLAMA)))
    ex.run(v, 1)
    eng = ex.engines[v.name]
    assert eng._paged and eng.page_size == 8
    assert eng.chunk_threshold == 8
    assert eng.n_pages == eng.max_batch * eng.max_len // 8
