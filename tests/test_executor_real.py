"""The Executor seam: control plane over real engines (ISSUE 2 tentpole).

``make_cluster(backend="real")`` serves a mixed stream through
master -> variant selection -> ``EngineExecutor`` (real continuous-batching
engines on reduced configs), and measured service times re-fit variant
profiles in place — the closed loop between data plane and control plane.
"""
import pytest

from repro.configs.registry import ARCHS
from repro.core.master import MasterConfig
from repro.core.worker import Executor, SimExecutor
from repro.sim.cluster import make_cluster

LLAMA = ARCHS["llama3.2-1b"]


def _done(q):
    return q.finish >= 0 and not q.failed


def test_sim_executor_is_the_default_and_satisfies_protocol():
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    w = next(iter(c.master.workers.values()))
    assert isinstance(w.executor, SimExecutor)
    assert isinstance(w.executor, Executor)
    v = next(iter(c.store.registry.variants.values()))
    assert w.executor.run(v, 4) == pytest.approx(v.profile.latency(4))


def test_real_backend_serves_and_calibrates_profiles():
    """End-to-end acceptance: a mixed stream runs through selection into
    real engines, and at least one variant's m/c is re-fit from measured
    service times."""
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    before = {v.name: (v.profile.m, v.profile.c)
              for v in c.store.registry.variants.values()}
    assert all(v.profile.source == "analytic"
               for v in c.store.registry.variants.values())
    # one early query (a batch-1 job), then a burst that the worker's
    # adaptive batching packs into a larger job -> two distinct batch
    # sizes observed -> refit
    qs = [c.api.online_query(mod_arch=LLAMA.name, latency_ms=600_000)]
    c.run_until(30.0)
    qs += [c.api.online_query(mod_arch=LLAMA.name, latency_ms=600_000)
           for _ in range(7)]
    c.run_until(300.0)
    assert all(_done(q) for q in qs), \
        [(q.qid, q.failed, q.finish) for q in qs]

    w = next(iter(c.master.workers.values()))
    ex = w.executor
    assert ex.engines, "no real engine was ever built"
    # real engines actually decoded tokens for every job
    assert sum(e.stats["tokens_generated"]
               for e in ex.engines.values()) > 0
    batches = {b for obs in ex.observations.values() for b in obs}
    assert len(batches) >= 2, batches

    measured = [v for v in c.store.registry.variants.values()
                if v.profile.source == "measured"]
    assert measured, "no profile was re-fit from measurements"
    for v in measured:
        assert (v.profile.m, v.profile.c) != before[v.name]
        assert v.profile.latency(1) > 0
        # peak_qps was recomputed against the measured fit
        assert v.profile.peak_qps == pytest.approx(
            v.profile.max_batch / v.profile.latency(v.profile.max_batch))


def test_real_backend_queries_see_measured_latency():
    """Virtual-clock query latency reflects real measured service time,
    not the analytic roofline guess."""
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False, cfg=cfg,
                     backend="real")
    q = c.api.online_query(mod_arch=LLAMA.name, latency_ms=600_000)
    c.run_until(60.0)
    assert _done(q)
    w = next(iter(c.master.workers.values()))
    obs = [t for per_b in w.executor.observations.values()
           for ts in per_b.values() for t in ts]
    assert obs
    # service portion of the query latency equals a measured duration
    assert q.finish - q.start == pytest.approx(obs[0])


def test_usecase_query_redispatch_reselects():
    """Regression (ISSUE 2 satellite): a use-case query that cannot be
    placed yet must retry via select_usecase — not fail because it carries
    neither arch nor variant."""
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    q = c.api.online_query(task="text-generation", dataset="openwebtext",
                           accuracy=0.5, latency_ms=600_000)
    assert q.task == "text-generation" and q.dataset == "openwebtext"
    # capacity appears only after the query has started retrying
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    c.run_until(120.0)
    assert _done(q), (q.failed, q.finish)
    assert q.variant


def test_variant_query_redispatch_reselects():
    """Same hole as above for variant-named queries: the user's mod_var
    choice must survive a failed first dispatch and retry."""
    c = make_cluster(n_accel=0, n_cpu=0, archs=[LLAMA], autoscale=False)
    vname = next(v.name for v in c.store.registry.variants.values()
                 if v.hardware == "tpu-v5e-1")
    q = c.api.online_query(mod_var=vname, latency_ms=600_000)
    assert q.variant == vname
    c.loop.schedule(0.6, lambda: c.master.add_worker("accel"))
    c.run_until(120.0)
    assert _done(q), (q.failed, q.finish)
    assert q.variant == vname


def test_variant_objects_stay_hashable():
    """The frozen Variant hashes its (identity-hashed, mutable) profile;
    sets/dict keys of Variants must keep working."""
    c = make_cluster(n_accel=1, archs=[LLAMA], autoscale=False)
    vs = list(c.store.registry.variants.values())
    assert len({v for v in vs}) == len(vs)
    assert vs[0] in {vs[0]}


def test_jax_executor_measured_keyed_by_prompt_len():
    """Regression (ISSUE 2 satellite): mixed-length calibration runs must
    not overwrite each other."""
    from repro.serving.engine import JaxExecutor
    ex = JaxExecutor({LLAMA.name: LLAMA.reduced()},
                     max_batch=2, max_len=32, decode_block=4, min_bucket=4)
    ex.execute(LLAMA.name, batch=2, prompt_len=4, max_new=2)
    ex.execute(LLAMA.name, batch=2, prompt_len=8, max_new=2)
    keys = set(ex.measured)
    assert keys == {(LLAMA.name, 2, 4), (LLAMA.name, 2, 8)}
    assert all(t > 0 for t in ex.measured.values())
