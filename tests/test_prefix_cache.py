"""Prefix caching with copy-on-write page sharing (ISSUE 7).

Host-side unit tests pin the PrefixCache index (chained digests,
longest-run lookup, registration races, eviction policies) and the
allocator's refcount/COW mechanics; the engine tests pin the acceptance
guarantee — greedy outputs with the prefix cache on are bit-identical to
the cache-off paged engine and the contiguous engine, across chunked
prefill, in-segment admission (staging ring), optimistic admission with
preemption, and forced preempt + re-admission — plus the hybrid clamp
(recurrent state cannot be recovered from shared pages) and the stats
counters the selection layer keys on.
"""
import numpy as np
import pytest

from repro.serving.engine import PageAllocator, PrefixCache


def _invariant(alloc):
    """free + cached + unique live pages == whole pool; refcounts match
    the holder lists exactly."""
    live = alloc.live_pages()
    uniq = set(live)
    assert len(alloc._free) + alloc.n_cached + len(uniq) == alloc.n_pages
    counts = {}
    for p in live:
        counts[p] = counts.get(p, 0) + 1
    assert counts == dict(alloc._refcnt)
    assert not (uniq & set(alloc._cached))
    assert not (uniq & set(alloc._free))


# ---------------------------------------------------------------------------
# PrefixCache host-side unit tests


def test_chain_digests_commit_to_whole_prefix():
    alloc = PageAllocator(8, 4)
    pc = PrefixCache(alloc, 4)
    toks = np.arange(13, dtype=np.int32)        # 3 full pages + 1 spare
    c = pc.chain(toks)
    assert len(c) == 3                          # partial page never hashed
    # shared prefix -> shared digests, then divergence poisons the chain
    other = toks.copy()
    other[5] = 999
    c2 = pc.chain(other)
    assert c2[0] == c[0]
    assert c2[1] != c[1] and c2[2] != c[2]
    # a differing *early* token changes every later digest (chaining)
    head = toks.copy()
    head[0] = 999
    assert all(a != b for a, b in zip(pc.chain(head), c))


def test_register_lookup_longest_indexed_run():
    alloc = PageAllocator(8, 4)
    pc = PrefixCache(alloc, 4)
    toks = np.arange(12, dtype=np.int32)
    digests = pc.chain(toks)
    alloc.reserve("a", 12)
    pages = alloc.cover("a", 12)
    pc.register(digests, pages)
    assert pc.lookup(toks) == pages
    assert pc.lookup(toks[:8]) == pages[:2]     # prefix of the prompt
    assert pc.lookup(toks[:7]) == pages[:1]     # partial page drops off
    assert pc.lookup(np.arange(100, 112, dtype=np.int32)) == []
    # unindex a middle page: the run stops there even though page 2
    # stays indexed (lookup needs a contiguous indexed chain)
    pc.unindex(pages[1])
    assert pc.lookup(toks) == pages[:1]
    _invariant(alloc)


def test_register_race_keeps_first_page():
    """Two slots racing the same prompt each keep their private copy;
    the first registration wins and the loser's page stays unindexed."""
    alloc = PageAllocator(8, 4)
    pc = PrefixCache(alloc, 4)
    toks = np.arange(4, dtype=np.int32)
    (h,) = pc.chain(toks)
    alloc.reserve("a", 4)
    alloc.reserve("b", 4)
    (pa,) = alloc.cover("a", 4)
    (pb,) = alloc.cover("b", 4)
    pc.register([h], [pa])
    pc.register([h], [pb])                      # raced duplicate
    assert pc.lookup(toks) == [pa]
    assert pb not in pc._hash_of
    # releasing the loser returns its page straight to the free list
    alloc.release("b")
    assert pb in alloc._free
    _invariant(alloc)


def test_release_retains_indexed_pages_until_pressure_evicts():
    """Indexed pages survive their last holder (cached, rc==0) and are
    reclaimed only when the free list runs dry; eviction unindexes."""
    alloc = PageAllocator(3, 4)
    pc = PrefixCache(alloc, 4)
    toks = np.arange(8, dtype=np.int32)
    alloc.reserve("a", 8)
    pages = alloc.cover("a", 8)
    pc.register(pc.chain(toks), pages)
    alloc.release("a")
    assert alloc.n_free == 1 and alloc.n_cached == 2
    assert pc.lookup(toks) == pages             # still serveable
    _invariant(alloc)
    # demand 3 pages: 1 free + 2 evictions, cache fully drained
    alloc.reserve("b", 12)
    got = alloc.cover("b", 12)
    assert len(got) == 3 and alloc.evictions == 2
    assert len(pc) == 0 and pc.lookup(toks) == []
    _invariant(alloc)


@pytest.mark.parametrize("policy,victim", [("lru", 0), ("fifo", 0)])
def test_eviction_policy_order(policy, victim):
    """lru evicts the page whose release is oldest; fifo evicts in
    registration order. With a single release batch the two agree; the
    distinguishing case re-touches page 0 (re-attach + re-release) so
    lru's recency order flips while fifo's registration order does not."""
    alloc = PageAllocator(2, 4)
    pc = PrefixCache(alloc, 4, policy=policy)
    toks = np.arange(8, dtype=np.int32)
    alloc.reserve("a", 8)
    pages = alloc.cover("a", 8)
    pc.register(pc.chain(toks), pages)
    alloc.release("a")                          # cached: [p0, p1]
    # re-touch p0: now p0 is most-recently released
    alloc.reserve("t", 4)
    alloc.attach("t", [pages[0]])
    alloc.release("t")                          # lru order: [p1, p0]
    alloc.reserve("b", 4)
    (got,) = alloc.cover("b", 4)
    expect = pages[1] if policy == "lru" else pages[victim]
    assert got == expect
    _invariant(alloc)


def test_attach_refcounts_and_cow_gives_private_page():
    alloc = PageAllocator(4, 4)
    pc = PrefixCache(alloc, 4)
    toks = np.arange(8, dtype=np.int32)
    alloc.reserve("a", 8)
    pages = alloc.cover("a", 8)
    pc.register(pc.chain(toks), pages)
    alloc.reserve("b", 8)
    alloc.attach("b", pages)
    assert alloc.refcount(pages[0]) == 2
    _invariant(alloc)
    # COW b's last page: b gets a fresh rc==1 page, a keeps the original
    old, new = alloc.cow("b", 1)
    assert old == pages[1] and new not in pages
    assert alloc.refcount(old) == 1 and alloc.refcount(new) == 1
    assert alloc.pages_of("a") == pages
    assert alloc.pages_of("b") == [pages[0], new]
    _invariant(alloc)
    alloc.release("a")
    alloc.release("b")
    # a's indexed pages cached, b's private COW page freed
    assert alloc.n_cached == 2 and alloc.n_free == 2
    _invariant(alloc)


def test_bad_policy_rejected():
    alloc = PageAllocator(2, 4)
    with pytest.raises(ValueError, match="policy"):
        PrefixCache(alloc, 4, policy="mru")


# ---------------------------------------------------------------------------
# randomized interleavings of the sharing life cycle


def run_share_ops(ops, n_pages, page_size, max_slots):
    """Drive the refcounting allocator through the prefix-sharing life
    cycle — register / attach (cache hit) / cow (shared-page write) /
    release-retains-cached / evict-under-pressure — checking the sharing
    invariants after every op:

    * ``free + cached + unique_live == n_pages`` (conservation);
    * every page's refcount equals the number of holders listing it;
    * eviction only ever takes rc==0 pages (checked in the hook itself);
    * ``cow`` hands back a private rc==1 page and the shared original
      keeps its other holders.

    Shared between the hypothesis property test in
    ``test_property_paged_alloc.py`` and the seeded fuzz mirror below
    (which runs without hypothesis).
    """
    alloc = PageAllocator(n_pages, page_size)
    indexed = set()                      # model of the prefix index
    alloc.retain = lambda p: p in indexed
    evicted = []

    def on_evict(p):
        assert alloc.refcount(p) == 0, "evicted a referenced page"
        indexed.discard(p)
        evicted.append(p)

    alloc.on_evict = on_evict
    live = {}                            # holder -> npos
    next_h = 0
    for kind, pick, npos in ops:
        npos = min(npos, n_pages * page_size)
        if kind == "admit":
            if len(live) >= max_slots or not alloc.can_reserve(npos):
                continue
            h = ("h", next_h)
            next_h += 1
            alloc.reserve(h, npos)
            alloc.cover(h, min(npos, page_size))
            live[h] = npos
        elif kind == "grow" and live:
            h = sorted(live)[pick % len(live)]
            grown = alloc.cover(h, npos)
            assert len(grown) == len(set(grown))
        elif kind == "register" and live:
            h = sorted(live)[pick % len(live)]
            pages = alloc.pages_of(h)
            if pages:
                indexed.add(pages[pick % len(pages)])
        elif kind == "attach" and live:
            # a cache hit: an indexed page (live elsewhere or cached)
            # gains a holder, within that holder's reservation
            h = sorted(live)[pick % len(live)]
            room = alloc.pages_needed(live[h]) - len(alloc.pages_of(h))
            cand = sorted(indexed)
            if cand and room > 0:
                alloc.attach(h, [cand[pick % len(cand)]])
        elif kind == "cow" and live:
            h = sorted(live)[pick % len(live)]
            pages = alloc.pages_of(h)
            shared = [i for i, p in enumerate(pages)
                      if alloc.refcount(p) > 1]
            if shared and alloc.n_avail > 0:
                idx = shared[pick % len(shared)]
                old, new = alloc.cow(h, idx)
                assert alloc.refcount(new) == 1
                assert alloc.refcount(old) >= 1
                assert alloc.pages_of(h)[idx] == new
        elif kind == "finish" and live:
            h = sorted(live)[pick % len(live)]
            alloc.release(h)
            del live[h]
        # ---- sharing invariants --------------------------------------
        held = alloc.live_pages()
        uniq = set(held)
        assert alloc.n_free + alloc.n_cached + len(uniq) == n_pages, \
            "free + cached + unique live != pool"
        counts = {}
        for p in held:
            counts[p] = counts.get(p, 0) + 1
        assert counts == dict(alloc._refcnt), "refcount drift"
        assert all(p in indexed for p in alloc._cached), \
            "cached page not indexed"
        assert not uniq & set(alloc._cached) and not uniq & set(alloc._free)
    for h in sorted(live):
        alloc.release(h)
    # drain: every page is free or retained-for-reuse, none lost
    assert alloc.n_free + alloc.n_cached == n_pages
    assert alloc.committed == 0 and not alloc.live_pages()
    return evicted


_SHARE_KINDS = ["admit", "grow", "register", "attach", "cow", "finish"]


def test_seeded_fuzz_sharing_invariants():
    """Deterministic mirror of the hypothesis sharing property: 200
    random interleavings from a pinned seed, runnable with or without
    hypothesis installed."""
    rng = np.random.default_rng(0x5EED)
    total_evictions = 0
    for _ in range(200):
        n_pages = int(rng.integers(1, 33))
        page_size = int(rng.integers(1, 13))
        max_slots = int(rng.integers(1, 7))
        n_ops = int(rng.integers(1, 81))
        ops = [(_SHARE_KINDS[int(rng.integers(len(_SHARE_KINDS)))],
                int(rng.integers(0, 2**31 - 1)), int(rng.integers(1, 97)))
               for _ in range(n_ops)]
        total_evictions += len(run_share_ops(ops, n_pages, page_size,
                                             max_slots))
    assert total_evictions > 0       # pressure path actually exercised


# ---------------------------------------------------------------------------
# engine acceptance: bit-identity + counters (real JAX models -> slow)

slow = pytest.mark.slow


@pytest.fixture(scope="module")
def dense():
    import jax
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _shared_stream(cfg, n=10, seed=3):
    """Mixed stream where most prompts open with one of two templates
    (two+ full 8-wide pages of sharable prefix each)."""
    rng = np.random.default_rng(seed)
    t1 = rng.integers(0, cfg.vocab, size=24).astype(np.int32)
    t2 = rng.integers(0, cfg.vocab, size=17).astype(np.int32)
    from repro.serving.engine import Request
    out = []
    for i in range(n):
        tpl = t1 if i % 2 == 0 else t2
        sfx = rng.integers(0, cfg.vocab,
                           size=int(rng.integers(2, 7))).astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([tpl, sfx]),
                           max_new_tokens=int(rng.integers(3, 9))))
    return out


def _run(model, params, reqs, **kw):
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(model, params, max_batch=4, max_len=64,
                        decode_block=8, **kw)
    eng.serve(reqs)
    return [tuple(map(int, r.tokens)) for r in reqs], eng


@slow
def test_prefix_cache_bit_identical_and_counts(dense):
    cfg, model, params = dense
    base, _ = _run(model, params, _shared_stream(cfg))
    kw = dict(page_size=8, n_pages=24, chunk_threshold=12)
    off, e_off = _run(model, params, _shared_stream(cfg), **kw)
    on, e_on = _run(model, params, _shared_stream(cfg),
                    prefix_cache=True, **kw)
    assert base == off == on
    assert e_off.stats["prefix_hits"] == 0
    s = e_on.stats
    assert s["prefix_hits"] > 0
    assert s["prefix_tokens_skipped"] >= s["prefix_hits"] * 8
    assert s["prefix_pages_reused"] * 8 >= s["prefix_tokens_skipped"]
    # the selection layer sees the hit rate through occupancy
    occ = e_on.occupancy
    for key in ("prefix_hits", "prefix_pages_reused", "cow_copies",
                "evictions"):
        assert occ[key] == float(s[key])
    # full drain: everything not cached for reuse is back on the free list
    assert e_on._alloc.n_free + e_on._alloc.n_cached == e_on.n_pages


@slow
def test_prefix_cache_with_staging_ring(dense):
    cfg, model, params = dense
    base, _ = _run(model, params, _shared_stream(cfg))
    got, eng = _run(model, params, _shared_stream(cfg), page_size=8,
                    n_pages=24, chunk_threshold=12, stage_slots=2,
                    prefix_cache=True)
    assert base == got
    # staged admissions bypass the lookup but their pages still register
    assert eng.stats["inseg_admissions"] > 0
    assert len(eng._prefix) > 0


@slow
def test_prefix_cache_under_optimistic_preemption(dense):
    """Small pool: optimistic admission preempts and the cache evicts
    under pressure — outputs still bit-identical, and eviction never
    broke an invariant (drain check)."""
    cfg, model, params = dense
    base, _ = _run(model, params, _shared_stream(cfg))
    got, eng = _run(model, params, _shared_stream(cfg), page_size=8,
                    n_pages=12, chunk_threshold=12, admission="optimistic",
                    prefix_cache=True)
    assert base == got
    assert eng.stats["preemptions"] > 0
    assert eng._alloc.n_free + eng._alloc.n_cached == eng.n_pages


@slow
def test_forced_preempt_readmission_rehits(dense):
    """A preempted victim's registered pages go cached on release; its
    replay re-hits the index instead of recomputing the prefix."""
    from repro.serving.engine import ServingEngine
    cfg, model, params = dense
    reqs = _shared_stream(cfg)
    base = [tuple(map(int,
                      _run(model, params, _shared_stream(cfg))[0][i]))
            for i in range(len(reqs))]
    eng = ServingEngine(model, params, max_batch=4, max_len=64,
                        decode_block=8, page_size=8, n_pages=24,
                        chunk_threshold=12, prefix_cache=True)
    for r in reqs:
        eng.submit(r)
    eng.step()
    h0 = eng.stats["prefix_hits"]
    victim = next(r.rid for r in eng._slot_req if r is not None)
    eng.preempt(victim)
    while eng.busy:
        eng.step()
    assert [tuple(map(int, r.tokens)) for r in reqs] == base
    assert eng.stats["prefix_hits"] > h0


@slow
def test_full_page_hit_triggers_cow(dense):
    """Two live requests sharing an exact-multiple-of-page prompt: the
    second's seat rewrites plen-1 inside the last shared page, which must
    copy-on-write (the first request still reads the original)."""
    import jax  # noqa: F401  (module fixture built already)
    from repro.serving.engine import Request, ServingEngine
    cfg, model, params = dense
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, size=16).astype(np.int32)  # 2 pages
    a = Request(rid=0, prompt=prompt.copy(), max_new_tokens=12)
    b = Request(rid=1, prompt=prompt.copy(), max_new_tokens=4)
    base_a = None
    for r, new in ((a, 12), (b, 4)):
        solo = ServingEngine(model, params, max_batch=2, max_len=64,
                             decode_block=4)
        rr = Request(rid=9, prompt=prompt.copy(), max_new_tokens=new)
        solo.serve([rr])
        if base_a is None:
            base_a = tuple(map(int, rr.tokens))
        else:
            base_b = tuple(map(int, rr.tokens))
    eng = ServingEngine(model, params, max_batch=2, max_len=64,
                        decode_block=4, page_size=8, n_pages=16,
                        chunk_threshold=12, prefix_cache=True)
    eng.submit(a)
    # a's prompt pages register once its position frontier passes them
    # (16 teacher-forced positions at decode_block=4)
    while len(eng._prefix) < 2:
        eng.step()
    assert eng.busy                 # a still mid-decode
    eng.submit(b)                   # full-page hit while a is live
    while eng.busy:
        eng.step()
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["cow_copies"] == 1
    assert tuple(map(int, a.tokens)) == base_a
    assert tuple(map(int, b.tokens)) == base_b


@slow
def test_hybrid_family_clamps_prefix_cache_off():
    """zamba2 carries O(1) recurrent leaves that shared KV pages cannot
    reconstruct: the knob clamps off and outputs stay exact."""
    import jax
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine
    cfg = ARCHS["zamba2-1.2b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base, _ = _run(model, params, _shared_stream(cfg, n=6))
    eng = ServingEngine(model, params, max_batch=4, max_len=64,
                        decode_block=8, page_size=8, chunk_threshold=12,
                        prefix_cache=True)
    assert eng._prefix is None
    reqs = _shared_stream(cfg, n=6)
    eng.serve(reqs)
    assert [tuple(map(int, r.tokens)) for r in reqs] == base
    assert eng.stats["prefix_hits"] == 0
