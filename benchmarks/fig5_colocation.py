"""Figs. 5/9: co-locating a large and a small model on one accelerator.

Paper finding: at low load sharing is free; at high load the small model
suffers (the large one is mostly unaffected) -> sharing must be managed.
INFaaS (autoscaling on) detects the SLO violations and scales out.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals
from benchmarks.common import Row, steady_metrics

LARGE = ARCHS["yi-9b"]        # Inception-ResNetV2 analogue
SMALL = ARCHS["llama3.2-1b"]  # MobileNetV1 analogue


def _run(shared: bool, rate_frac: float, autoscale: bool = False,
         t_end: float = 40.0) -> Dict[str, Dict[str, float]]:
    c = make_cluster(n_accel=1 if shared else 2, archs=[LARGE, SMALL],
                     autoscale=autoscale)
    pick = {}
    for cfgA in (LARGE, SMALL):
        v = [x for x in c.store.registry.variants.values()
             if x.arch == cfgA.name and x.hardware == "tpu-v5e-1"
             and x.batch_opt == 1 and "int8" in x.framework][0]
        pick[cfgA.name] = v
    workers = list(c.master.workers.values())
    if shared:
        for v in pick.values():
            workers[0].load_variant(v)
    else:
        workers[0].load_variant(pick[LARGE.name])
        workers[1].load_variant(pick[SMALL.name])
    c.run_until(10.0)
    rate_large = pick[LARGE.name].profile.peak_qps * rate_frac
    rate_small = pick[SMALL.name].profile.peak_qps * rate_frac
    for arch, rate, seed in ((LARGE.name, rate_large, 1),
                             (SMALL.name, rate_small, 2)):
        vn = pick[arch].name
        poisson_arrivals(
            c.loop, (lambda r: lambda t: r)(rate),
            (lambda vv: lambda t: c.api.submit(
                QuerySpec.variant(vv, latency_ms=1000)))(vn),
            t_end=t_end, seed=seed)
    c.run_until(10.0 + t_end + 20.0)
    out = {}
    for arch in (LARGE.name, SMALL.name):
        qs = [q for q in c.master.metrics
              if q.variant.startswith(arch) and q.kind == "online"]
        out[arch] = steady_metrics(qs, 10.0, 10.0 + t_end, warmup=5.0)
    return out


def run(verbose: bool = True) -> List[Row]:
    # high load = each model at 45% of its solo capacity: fine alone, but
    # the shared device is then at ~90% combined -> queueing interference
    lo_alone = _run(shared=False, rate_frac=0.15)
    lo_shared = _run(shared=True, rate_frac=0.15)
    hi_alone = _run(shared=False, rate_frac=0.45)
    hi_shared = _run(shared=True, rate_frac=0.45)

    def r(metric, a, b):
        return b[metric] / max(a[metric], 1e-9)
    small_lo = r("p50_ms", lo_alone[SMALL.name], lo_shared[SMALL.name])
    small_hi = r("p50_ms", hi_alone[SMALL.name], hi_shared[SMALL.name])
    large_hi = r("p50_ms", hi_alone[LARGE.name], hi_shared[LARGE.name])
    if verbose:
        print(f"# fig5: small-model p50 sharing penalty: low load "
              f"{small_lo:.2f}x, high load {small_hi:.2f}x; "
              f"large model at high load {large_hi:.2f}x")
    return [
        ("fig5_small_penalty_lowload_x", small_lo, "shared_vs_alone_p50"),
        ("fig5_small_penalty_highload_x", small_hi, "shared_vs_alone_p50"),
        ("fig5_large_penalty_highload_x", large_hi, "shared_vs_alone_p50"),
    ]
