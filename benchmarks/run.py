"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows on stdout (detailed per-figure
tables as '#' comment lines above each block).
"""
from __future__ import annotations

import argparse
import sys
import time


MODULES = [
    "fig2_variant_space",
    "fig8_latency_fit",
    "fig15_overhead",
    "fig3_replication_batching",
    "fig5_colocation",
    "fig10_online_offline",
    "fig11_autoscaling",
    "fig13_realistic",
    "roofline_table",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substring filter")
    args = ap.parse_args()
    mods = MODULES
    if args.only:
        keys = args.only.split(",")
        mods = [m for m in MODULES if any(k in m for k in keys)]
    print("name,us_per_call,derived")
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(verbose=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"# {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            print(f"{name}_FAILED,0,{type(e).__name__}")
            continue
        dt = time.time() - t0
        print(f"# [{name} took {dt:.1f}s]")
        for row_name, val, derived in rows:
            print(f"{row_name},{val:.6g},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
