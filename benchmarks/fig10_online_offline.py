"""Fig. 10: co-locating online and offline queries on one worker.

Paper finding: INFaaS keeps online latency/throughput intact by throttling
offline work under SLO pressure, while the offline job absorbs slack.
"""
from __future__ import annotations

from typing import List

from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals, ramp_rate
from benchmarks.common import Row, steady_metrics

ARCH = ARCHS["llama3.2-1b"]


def _run(with_offline: bool, t_end: float = 80.0):
    c = make_cluster(n_accel=1, archs=[ARCH], autoscale=False)
    if with_offline:
        job = c.api.submit(QuerySpec.arch(ARCH.name, mode="offline",
                                          n_inputs=5000)).job
    else:
        job = None
    rate = ramp_rate(t_end, 2.0, 120.0)
    poisson_arrivals(
        c.loop, rate,
        lambda t: c.api.submit(QuerySpec.arch(ARCH.name, latency_ms=500)),
        t_end=t_end, seed=11)
    c.run_until(t_end + 30.0)
    online = [q for q in c.master.metrics if q.kind == "online"]
    m = steady_metrics(online, 0.0, t_end, warmup=10.0)
    return m, job


def run(verbose: bool = True) -> List[Row]:
    alone, _ = _run(False)
    shared, job = _run(True)
    thr_ratio = shared["throughput_qps"] / max(alone["throughput_qps"], 1e-9)
    lat_ratio = shared["p50_ms"] / max(alone["p50_ms"], 1e-9)
    if verbose:
        print(f"# fig10: online alone p50={alone['p50_ms']:.1f}ms "
              f"viol={alone['violation_rate']:.3f} | with offline "
              f"p50={shared['p50_ms']:.1f}ms viol={shared['violation_rate']:.3f}"
              f" | offline processed {job.processed}/{job.total_inputs}")
    return [
        ("fig10_online_thr_ratio", thr_ratio, "colocated_vs_alone"),
        ("fig10_online_p50_ratio", lat_ratio, "colocated_vs_alone"),
        ("fig10_offline_processed", float(job.processed),
         f"of_{job.total_inputs}_best_effort"),
    ]
