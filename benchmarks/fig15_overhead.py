"""Fig. 15: decision (variant+worker selection) overhead in microseconds,
for ModVar / ModArch / Use-Case queries, loaded (L) and not-loaded (NL).

These are REAL wall-clock measurements of the selection code, the direct
analogue of the paper's 1.6ms cached / <12% of serving time result.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs.registry import ARCHS
from repro.core import profiler as prof
from repro.core.metadata import InstanceState, MetadataStore
from repro.core.selection import VariantSelector
from benchmarks.common import Row

REPEATS = 300


def _time_us(fn) -> float:
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        fn()
    return (time.perf_counter() - t0) / REPEATS * 1e6


def run(verbose: bool = True) -> List[Row]:
    store = MetadataStore()
    prof.register_all(store.registry, list(ARCHS.values()))
    store.upsert_worker("w0", ("cpu-host", "tpu-v5e-1"), 0.0)
    store.heartbeat("w0", {"cpu-host": 0.1, "tpu-v5e-1": 0.1},
                    {"cpu-host": 0.0, "tpu-v5e-1": 0.0}, 0.0)
    arch = "llama3.2-1b"
    target = [v for v in store.registry.variants_of(arch)
              if v.hardware == "tpu-v5e-1" and v.batch_opt == 1][0]

    rows: List[Row] = []
    # --- not loaded (NL): full search each time (cache cleared)
    sel = VariantSelector(store)
    nl_var = _time_us(lambda: sel.select_variant(target.name, 1))
    def arch_nl():
        sel._cache.clear()
        sel.select_arch(arch, 1, 0.01)
    nl_arch = _time_us(arch_nl)
    def uc_nl():
        sel._cache.clear()
        sel.select_usecase("text-generation", "openwebtext", 0.6, 1, 0.01)
    nl_uc = _time_us(uc_nl)

    # --- loaded (L): variant running; decision-cache hits
    store.set_instance(InstanceState(variant=target.name, worker="w0",
                                     running=True))
    l_var = _time_us(lambda: sel.select_variant(target.name, 1))
    sel.select_arch(arch, 1, 0.01)   # prime cache
    l_arch = _time_us(lambda: sel.select_arch(arch, 1, 0.01))
    sel.select_usecase("text-generation", "openwebtext", 0.6, 1, 0.01)
    l_uc = _time_us(lambda: sel.select_usecase(
        "text-generation", "openwebtext", 0.6, 1, 0.01))

    serve_ms = target.profile.latency(1) * 1e3
    frac = (l_uc / 1e3) / serve_ms
    if verbose:
        print(f"# fig15 decision latency (us): "
              f"ModVar L={l_var:.0f} NL={nl_var:.0f} | "
              f"ModArch L={l_arch:.0f} NL={nl_arch:.0f} | "
              f"UseCase L={l_uc:.0f} NL={nl_uc:.0f}")
        print(f"# fig15 cached use-case decision = {frac*100:.1f}% of the "
              f"{serve_ms:.2f}ms serve time (paper: <12%)")
    rows += [
        ("fig15_modvar_loaded", l_var, "us_per_decision"),
        ("fig15_modvar_notloaded", nl_var, "us_per_decision"),
        ("fig15_modarch_loaded", l_arch, "us_per_decision"),
        ("fig15_modarch_notloaded", nl_arch, "us_per_decision"),
        ("fig15_usecase_loaded", l_uc, "us_per_decision"),
        ("fig15_usecase_notloaded", nl_uc, "us_per_decision"),
        ("fig15_frac_of_serve_time", frac, f"serve_{serve_ms:.2f}ms"),
    ]
    return rows
