"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.worker import Query
from repro.sim.cluster import Cluster

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def steady_metrics(queries: List[Query], t0: float, t1: float,
                   warmup: float = 20.0) -> Dict[str, float]:
    """Throughput / violation-rate over [t0+warmup, t1] (paper Fig. 13)."""
    done = [q for q in queries
            if q.finish >= t0 + warmup and q.finish <= t1 and not q.failed]
    viol = [q for q in done if q.violated]
    lat = [q.latency for q in done]
    span = max(t1 - t0 - warmup, 1e-9)
    return {
        "completed": len(done),
        "throughput_qps": sum(q.n_inputs for q in done) / span,
        "violation_rate": len(viol) / max(len(done), 1),
        "p50_ms": pct(lat, 50) * 1e3,
        "p99_ms": pct(lat, 99) * 1e3,
    }


def cluster_cost(c: Cluster, t_end: float) -> float:
    """Chip-second cost units: sum of worker hardware cost rates x uptime
    (approximated as full-run uptime for workers alive at the end plus
    heartbeat-observed lifetime for the dead)."""
    from repro.sim import hardware as HW
    cost = 0.0
    for w in c.store.workers.values():
        alive_span = (w.heartbeat if not w.alive else t_end)
        rate = sum(HW.HARDWARE[h].cost_rate for h in w.hardware
                   if h != "cpu-host") or HW.HARDWARE["cpu-host"].cost_rate
        cost += rate * max(alive_span, 0.0)
    return cost


class UsageCostTracker:
    """Paper §8.4 cost accounting: at each timestep, charge for an
    accelerator only if an accelerator model is loaded, else CPU rate."""

    def __init__(self, c: Cluster, period: float = 2.0):
        from repro.sim import hardware as HW
        self.cost = 0.0
        self.period = period

        def sample():
            for w in c.master.workers.values():
                if not w.alive:
                    continue
                accel_used = any(li.variant.is_accel
                                 for li in w.instances.values())
                cpu_used = any(not li.variant.is_accel
                               for li in w.instances.values())
                rate = 0.0
                if accel_used:
                    rate += sum(HW.HARDWARE[h].cost_rate
                                for h in w.hardware if h != "cpu-host")
                if cpu_used or not accel_used:
                    rate += HW.HARDWARE["cpu-host"].cost_rate
                self.cost += rate * period
        c.loop.every(period, sample)


def util_series(c: Cluster) -> Dict[str, float]:
    cpu, accel = [], []
    for w in c.store.workers.values():
        if not w.alive:
            continue
        for h, u in w.util.items():
            (cpu if h == "cpu-host" else accel).append(u)
    return {"cpu_util": float(np.mean(cpu)) if cpu else 0.0,
            "accel_util": float(np.mean(accel)) if accel else 0.0}


class UtilTracker:
    """Time-averaged cluster utilization + peak worker count (fig. 14)."""

    def __init__(self, c: Cluster, period: float = 2.0, t_end: float = None):
        self.cpu: List[float] = []
        self.accel: List[float] = []
        self.peak_workers = 0

        def sample():
            if t_end is not None and c.loop.now() > t_end:
                return
            s = util_series(c)
            self.cpu.append(s["cpu_util"])
            self.accel.append(s["accel_util"])
            self.peak_workers = max(
                self.peak_workers,
                sum(1 for w in c.store.workers.values() if w.alive))
        c.loop.every(period, sample)

    def summary(self) -> Dict[str, float]:
        return {
            "cpu_util": float(np.mean(self.cpu)) if self.cpu else 0.0,
            "accel_util": float(np.mean(self.accel)) if self.accel else 0.0,
            "peak_workers": float(self.peak_workers),
        }


def baseline_variant(c: Cluster, arch: str):
    """Paper §8.5 baseline user choice: fastest CPU variant if one exists,
    else the fastest smallest-batch accelerator variant (restricted to
    hardware the cluster's workers actually have)."""
    have = {h for w in c.master.workers.values() for h in w.hardware}
    vs = [v for v in c.store.registry.variants_of(arch) if v.hardware in have]
    cpu = [v for v in vs if not v.is_accel]
    if cpu:
        return min(cpu, key=lambda v: v.profile.latency(1))
    accel = sorted(vs, key=lambda v: (v.batch_opt, v.profile.latency(1)))
    return accel[0]
