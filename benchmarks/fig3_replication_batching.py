"""Figs. 3/4: replication vs adaptive batching, accelerator vs host CPU.

Paper finding: on the accelerator, adaptive batching lifts throughput ~2.5x
with little latency cost while replication barely helps (and is disallowed);
on CPU, replication doubles throughput while batching helps little.
"""
from __future__ import annotations

from typing import List

from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals
from benchmarks.common import Row, steady_metrics

ARCH = ARCHS["llama3.2-1b"]


def _drive(kind: str, batch_opt: int, replicas: int, rate: float,
           t_end: float = 40.0):
    c = make_cluster(n_accel=1 if kind == "accel" else 0,
                     n_cpu=0 if kind == "accel" else 1,
                     archs=[ARCH], autoscale=False,
                     )
    # pin the exact variant under test; disable worker autoscaling
    for w in c.master.workers.values():
        w.cfg = w.cfg.__class__(**{**w.cfg.__dict__})
    hw = "tpu-v5e-1" if kind == "accel" else "cpu-host"
    cands = [v for v in c.store.registry.variants.values()
             if v.hardware == hw and v.batch_opt == batch_opt]
    v = cands[0]
    w = next(iter(c.master.workers.values()))
    w.load_variant(v, replicas=replicas)
    c.run_until(10.0)
    poisson_arrivals(
        c.loop, lambda t: rate,
        lambda t: c.api.submit(QuerySpec.variant(v.name, latency_ms=60_000)),
        t_end=t_end, seed=7)
    c.run_until(10.0 + t_end + 10.0)
    m = steady_metrics(c.master.metrics, 10.0, 10.0 + t_end, warmup=5.0)
    return m


def run(verbose: bool = True) -> List[Row]:
    # drive each configuration at 90% of ITS OWN capacity and report the
    # sustained throughput + median latency (paper Figs. 3/4 axes)
    from repro.core import profiler as prof
    from repro.sim import hardware as HW
    b1 = prof.analytic_profile(ARCH, HW.HARDWARE["tpu-v5e-1"], "bf16", 1)
    b8 = prof.analytic_profile(ARCH, HW.HARDWARE["tpu-v5e-1"], "bf16", 8)
    accel_b1 = _drive("accel", 1, 1, b1.peak_qps * 0.9)
    accel_b8 = _drive("accel", 8, 1, b8.peak_qps * 0.9)
    cpu = prof.analytic_profile(ARCH, HW.HARDWARE["cpu-host"], "bf16", 8)
    cpu_r1 = _drive("cpu", 8, 1, cpu.peak_qps * 0.9)
    cpu_r2 = _drive("cpu", 8, 2, cpu.peak_qps * 1.8)
    batching_gain = accel_b8["throughput_qps"] / max(
        accel_b1["throughput_qps"], 1e-9)
    replication_gain = cpu_r2["throughput_qps"] / max(
        cpu_r1["throughput_qps"], 1e-9)
    lat_cost = accel_b8["p50_ms"] / max(accel_b1["p50_ms"], 1e-9)
    if verbose:
        print(f"# fig3: accel b1 {accel_b1['throughput_qps']:.0f} q/s "
              f"p50 {accel_b1['p50_ms']:.1f} ms | "
              f"accel b8 {accel_b8['throughput_qps']:.0f} q/s "
              f"p50 {accel_b8['p50_ms']:.1f} ms")
        print(f"# fig4: cpu 1-rep {cpu_r1['throughput_qps']:.1f} q/s "
              f"p50 {cpu_r1['p50_ms']:.0f} ms | cpu 2-rep "
              f"{cpu_r2['throughput_qps']:.1f} q/s "
              f"p50 {cpu_r2['p50_ms']:.0f} ms")
    return [
        ("fig3_accel_batching_throughput_x", batching_gain,
         f"paper_~2.5x_latency_cost_{lat_cost:.2f}x"),
        ("fig4_cpu_replication_throughput_x", replication_gain,
         "paper_~2x_2rep_vs_1rep"),
    ]
