"""Fig. 2: the model-variant search space (latency / memory / accuracy).

The paper plots 44 architectures x 270 variants for image classification;
here the profiler-generated zoo for the 10 assigned architectures.
"""
from __future__ import annotations

from typing import List

from repro.configs.registry import ARCHS
from repro.core import profiler as prof
from repro.core.abstraction import Registry
from benchmarks.common import Row


def run(verbose: bool = True) -> List[Row]:
    reg = Registry()
    n = prof.register_all(reg, list(ARCHS.values()))
    variants = list(reg.variants.values())
    lats = [v.profile.latency(1) * 1e3 for v in variants]
    mems = [v.profile.peak_memory / 2**20 for v in variants]
    if verbose:
        print(f"# fig2: {len(reg.archs)} architectures, {n} variants")
        print("# variant,hardware,batch_opt,lat_b1_ms,load_s,mem_MiB,accuracy")
        for v in sorted(variants, key=lambda v: (v.arch, v.name)):
            print(f"#   {v.name},{v.hardware},{v.batch_opt},"
                  f"{v.profile.latency(1)*1e3:.3f},"
                  f"{v.profile.load_latency:.2f},"
                  f"{v.profile.peak_memory/2**20:.0f},{v.accuracy:.3f}")
    lat_spread = max(lats) / min(lats)
    mem_spread = max(mems) / min(mems)
    return [
        ("fig2_num_variants", float(n), f"{len(reg.archs)}_archs"),
        ("fig2_latency_spread_x", lat_spread,
         f"{min(lats):.2f}-{max(lats):.1f}ms"),
        ("fig2_memory_spread_x", mem_spread,
         f"{min(mems):.0f}-{max(mems):.0f}MiB"),
    ]
