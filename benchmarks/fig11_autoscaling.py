"""Fig. 11: autoscaling strategies under a load+SLO swing.

ResNet50 analogue (llama3.2-1b) on one accelerator worker; load ramps
5 -> peak -> 5 images/s while the SLO switches 500ms -> 20ms -> 500ms.
Strategies: GPU-S (static accel b8), CPU-S (static 2 CPU replicas),
INDV (replication only, no upgrades), INFaaS (replication + upgrading).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.core.master import MasterConfig
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals
from benchmarks.common import Row, UsageCostTracker, steady_metrics

ARCH = ARCHS["llama3.2-1b"]
# relaxed ramp, strict peak, long relaxed tail (the tail is where INFaaS's
# downgrade ladder pays off vs the statically-provisioned GPU)
T_PHASE = (30.0, 50.0, 140.0)


def _load_and_slo(c, peak_rate: float, seed: int, variant: str = None):
    t1, t2, t3 = T_PHASE
    total = t1 + t2 + t3
    tracker = UsageCostTracker(c)

    def rate(t):
        if t < t1:
            return 5.0 + (0.3 * peak_rate - 5.0) * t / t1
        if t < t1 + t2:
            u = (t - t1) / t2
            return 0.3 * peak_rate + (peak_rate - 0.3 * peak_rate) * \
                (1 - abs(2 * u - 1))
        return max(5.0, 0.3 * peak_rate * (1 - (t - t1 - t2) / t3))

    def slo_ms(t):
        return 20.0 if t1 <= t < t1 + t2 else 500.0

    def fire(t):
        # baselines pin the user-chosen variant; INFaaS is model-less
        if variant is not None:
            c.api.submit(QuerySpec.variant(variant, latency_ms=slo_ms(t)))
        else:
            c.api.submit(QuerySpec.arch(ARCH.name, latency_ms=slo_ms(t)))

    poisson_arrivals(c.loop, rate, fire, t_end=total, seed=seed)
    c.run_until(total + 20.0)
    m = steady_metrics(c.master.metrics, 0.0, total, warmup=5.0)
    m["cost"] = tracker.cost
    return m


def _static(variant_filter, replicas: int = 1, kind: str = "accel",
            worker_autoscale: bool = False, allow_upgrade: bool = True):
    cfg = MasterConfig(worker_autoscale=worker_autoscale,
                       allow_upgrade=allow_upgrade)
    c = make_cluster(n_accel=1 if kind == "accel" else 0,
                     n_cpu=0 if kind == "accel" else 1,
                     archs=[ARCH], autoscale=False, cfg=cfg)
    v = [x for x in c.store.registry.variants.values() if variant_filter(x)][0]
    w = next(iter(c.master.workers.values()))
    w.load_variant(v, replicas=replicas)
    c.run_until(5.0)
    return c, v


def run(verbose: bool = True) -> List[Row]:
    from repro.core import profiler as prof
    from repro.sim import hardware as HW
    peak = prof.analytic_profile(
        ARCH, HW.HARDWARE["tpu-v5e-1"], "bf16", 8).peak_qps * 0.9

    results: Dict[str, Dict[str, float]] = {}

    c, v = _static(lambda v: v.hardware == "tpu-v5e-1" and v.batch_opt == 8
                   and "bf16" in v.framework)
    results["GPU-S"] = _load_and_slo(c, peak, seed=1, variant=v.name)

    c, v = _static(lambda v: v.hardware == "cpu-host"
                   and "bf16" in v.framework, replicas=2, kind="cpu")
    results["CPU-S"] = _load_and_slo(c, peak, seed=2, variant=v.name)

    # INDV: user-pinned accel batch-1 variant + CPU replication only
    c, v = _static(lambda v: v.hardware == "tpu-v5e-1" and v.batch_opt == 1
                   and "bf16" in v.framework,
                   worker_autoscale=True, allow_upgrade=False)
    results["INDV"] = _load_and_slo(c, peak, seed=3, variant=v.name)

    c = make_cluster(n_accel=1, archs=[ARCH], autoscale=False)
    results["INFaaS"] = _load_and_slo(c, peak, seed=4)

    if verbose:
        for name, m in results.items():
            print(f"# fig11 {name:7s}: thr={m['throughput_qps']:8.1f} q/s "
                  f"viol={m['violation_rate']:.3f} p50={m['p50_ms']:.2f}ms "
                  f"cost={m['cost']:.0f}")
    inf = results["INFaaS"]
    rows = [("fig11_infaas_vs_gpus_cost",
             results["GPU-S"]["cost"] / max(inf["cost"], 1e-9),
             "gpu_static_cost_x_infaas"),
            ("fig11_infaas_vs_cpus_thr",
             inf["throughput_qps"] /
             max(results["CPU-S"]["throughput_qps"], 1e-9),
             "throughput_x_cpu_static"),
            ("fig11_infaas_vs_indv_viol",
             results["INDV"]["violation_rate"] /
             max(inf["violation_rate"], 1e-3),
             "indv_viol_x_infaas")]
    return rows
