"""Engine data-plane throughput: seed-style waves vs continuous batching.

Drives mixed-length request streams (8 slots, prompt lengths 4..28, decode
lengths mixed up to max_new=32) through both real-execution engines on
host CPU. Three phases per engine:

* ``cold``    — first stream ever; includes all XLA compiles.
* ``steady``  — five further streams with fresh shape mixes (real traffic:
  every stream has new (batch, prompt_len, max_new) combinations). This is
  the serving steady state and the headline number: the wave engine keeps
  recompiling here (its executables are keyed on exact wave shapes), the
  continuous engine has a closed bucket set and never recompiles.
* ``warm_repeat`` — re-serving the cold stream verbatim (every wave-shape
  executable already cached): pure-execution comparison, the wave
  engine's best case.

Measures decode tokens/sec and compile counts, writes
``BENCH_engine.json`` at the repo root.

Run:  PYTHONPATH=src python benchmarks/fig_engine_throughput.py
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str]

N_REQS = 32
SLOTS = 8
MAX_NEW = 32
MAX_LEN = 64            # max prompt 28 + max_new 32
DECODE_BLOCK = 32
STEADY_STREAMS = 5


def _stream(cfg, seed: int):
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(4, 29))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(4, MAX_NEW + 1)))
            for i in range(N_REQS)]


def _tokens(reqs) -> int:
    return sum(r.max_new_tokens for r in reqs)


def _drive(engine, cfg) -> dict:
    res = {}
    reqs = _stream(cfg, 0)
    t0 = time.perf_counter()
    engine.serve(reqs)
    dt = time.perf_counter() - t0
    res["cold_s"] = dt
    res["toks_per_s_cold"] = _tokens(reqs) / dt

    total, t0 = 0, time.perf_counter()
    for seed in range(1, 1 + STEADY_STREAMS):
        reqs = _stream(cfg, seed)
        total += _tokens(reqs)
        engine.serve(reqs)
    dt = time.perf_counter() - t0
    res["steady_s"] = dt
    res["toks_per_s_steady"] = total / dt

    reqs = _stream(cfg, 0)
    t0 = time.perf_counter()
    engine.serve(reqs)
    dt = time.perf_counter() - t0
    res["warm_repeat_s"] = dt
    res["toks_per_s_warm_repeat"] = _tokens(reqs) / dt
    res.update({k: v for k, v in engine.stats.items()
                if k.endswith("_traces")})
    return res


def run(verbose: bool = True) -> List[Row]:
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine, WaveEngine

    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    wave = _drive(WaveEngine(model, params, max_batch=SLOTS), cfg)
    cont = _drive(ServingEngine(model, params, max_batch=SLOTS,
                                max_len=MAX_LEN, decode_block=DECODE_BLOCK),
                  cfg)

    out = {
        "workload": {"n_requests_per_stream": N_REQS, "slots": SLOTS,
                     "prompt_len": "4..28", "max_new": f"4..{MAX_NEW}",
                     "steady_streams": STEADY_STREAMS, "arch": cfg.name,
                     "backend": jax.default_backend()},
        "seed_wave": wave,
        "continuous": cont,
        "speedup_steady": (cont["toks_per_s_steady"]
                           / wave["toks_per_s_steady"]),
        "speedup_cold": cont["toks_per_s_cold"] / wave["toks_per_s_cold"],
        "speedup_warm_repeat": (cont["toks_per_s_warm_repeat"]
                                / wave["toks_per_s_warm_repeat"]),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        for name, r in (("seed_wave", wave), ("continuous", cont)):
            print(f"# {name}: cold {r['toks_per_s_cold']:.0f} tok/s | "
                  f"steady {r['toks_per_s_steady']:.0f} tok/s | "
                  f"warm-repeat {r['toks_per_s_warm_repeat']:.0f} tok/s | "
                  f"traces prefill={r['prefill_traces']} "
                  f"decode={r['decode_traces']}")
        print(f"# speedup: steady {out['speedup_steady']:.2f}x, "
              f"warm-repeat {out['speedup_warm_repeat']:.2f}x, "
              f"cold {out['speedup_cold']:.2f}x -> {path}")
    return [
        ("engine_steady_tok_s_wave", wave["toks_per_s_steady"], "baseline"),
        ("engine_steady_tok_s_cont", cont["toks_per_s_steady"],
         f"{out['speedup_steady']:.2f}x"),
        ("engine_warm_repeat_tok_s_cont", cont["toks_per_s_warm_repeat"],
         f"{out['speedup_warm_repeat']:.2f}x"),
    ]


if __name__ == "__main__":
    run()
