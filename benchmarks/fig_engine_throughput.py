"""Engine data-plane throughput: seed-style waves vs continuous batching.

Drives mixed-length request streams (8 slots, prompt lengths 4..28, decode
lengths mixed up to max_new=32) through both real-execution engines on
host CPU. Three phases per engine:

* ``cold``    — first stream ever; includes all XLA compiles.
* ``steady``  — five further streams with fresh shape mixes (real traffic:
  every stream has new (batch, prompt_len, max_new) combinations). This is
  the serving steady state and the headline number: the wave engine keeps
  recompiling here (its executables are keyed on exact wave shapes), the
  continuous engine has a closed bucket set and never recompiles.
* ``warm_repeat`` — re-serving the cold stream verbatim (every wave-shape
  executable already cached): pure-execution comparison, the wave
  engine's best case.

Measures decode tokens/sec and compile counts, writes
``BENCH_engine.json`` at the repo root.

``--scenario long_tail`` instead drives the paged-KV capacity comparison
(-> ``BENCH_engine_paged.json``): a long-tail stream — mostly-short
prompts with rare near-``max_len`` ones — served by (a) the contiguous
engine, whose slot count is pinned to ``pool_positions / max_len`` by the
worst case, and (b) the paged engine on the *same pool bytes* with 4x the
slots, pages handed out per actual length (plus chunked prefill for the
long prompts). Records achieved concurrent-slot count alongside tok/s;
the paged engine must admit strictly more concurrent requests than
``max_batch_contiguous = pool_positions / max_len``.

``--scenario churn`` drives the in-segment-admission comparison
(-> ``BENCH_engine_churn.json``): a Poisson stream of short requests with
mixed decode lengths, arriving faster than the engine drains them. With
boundary-only admission a slot that finishes mid-segment idles until the
``lax.while_loop`` exits and the next request waits for the ``step()``
boundary (plus its own prefill dispatch); with ``stage_slots=N`` the
fused segment pulls staged requests into freed slots *inside* the loop —
fewer segments (and prefill dispatches) per retired request, higher
goodput, lower p99 queue delay, at identical engine config.

``--scenario pressure`` drives the graceful-degradation comparison
(-> ``BENCH_engine_pressure.json``): a burst of requests against a paged
KV pool sized at ~50% of their aggregate worst-case demand. Worst-case
admission serializes — each admitted request reserves pages it mostly
never touches, so concurrency is pinned by paper capacity. Optimistic
admission gates on *expected* usage, fills every slot, and when the pool
actually runs dry preempts the slackest victim (free its pages, park it
host-side, later re-admit by teacher-forcing its full prefix back
through chunked prefill — bit-identical recovery). Reports goodput,
SLO-violation rate, preemption / pressure-stall counts for both modes,
and checks optimistic outputs token-for-token against an uncontended
big-pool reference. The headline: optimistic serves strictly more
concurrent requests on the same pool with zero output divergence. (On
this host-CPU harness the extra concurrency is not free — batch-8 steps
cost ~2x batch-4 steps, and every preemption replays its prefix — so the
closed-burst goodput favors worst-case here; on a memory-bound
accelerator the wider batch is the whole point.)

``--scenario shared_prefix`` drives the prefix-cache comparison
(-> ``BENCH_engine_shared_prefix.json``): a few long templates (system
prompts) fan out into many requests with short unique suffixes, served
twice — once on the plain paged engine, once with ``prefix_cache=True``.
The cache hashes prompts at page granularity (chained digests), attaches
already-computed template pages to new slots (refcounted, copy-on-write
on any write into a shared page), and skips the covered prefill: the
seat teacher-forces from the first uncached token. Reports prefill
tokens skipped, hit rate, COW/eviction counts, and the prefill goodput
win (prompt tokens / tokens actually computed), with outputs checked
bit-identical against the cache-off engine.

``--scenario wall_stream`` drives the wall-clock serving runtime
(-> ``BENCH_engine_wall.json``): the full INFaaS control plane on
``RealClock`` — stepper-threaded engines, live seeded Poisson arrivals
submitted from a client thread, tokens streamed back per decode segment.
Reports time-to-first-token p50/p99 alongside completion latency and
goodput: with ``max_new >> decode_block`` the first segment retires long
before the full decode, so streaming TTFT p50 sits well below
completion p50 at identical goodput (same run, same served stream).

Run:  PYTHONPATH=src python benchmarks/fig_engine_throughput.py \
          [--scenario classic|long_tail|churn|pressure|shared_prefix|\
wall_stream|all] \
          [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str]

N_REQS = 32
SLOTS = 8
MAX_NEW = 32
MAX_LEN = 64            # max prompt 28 + max_new 32
DECODE_BLOCK = 32
STEADY_STREAMS = 5

# churn scenario (in-segment admission vs boundary-only). Short requests
# against long fused segments: boundary-only admission pays a harvest +
# prefill + dispatch boundary every ~(max_new) steps, while in-segment
# admission lets one 64-step dispatch retire many requests per slot.
CH_SLOTS = 4            # few slots + short requests = mid-segment churn
CH_MAX_LEN = 64
CH_DECODE_BLOCK = 64    # long segments amortize dispatch + sync overhead
CH_N_REQS = 64
CH_PROMPT = (2, 4)      # tiny prompts: teacher-forcing adds 1..3 steps
CH_MAX_NEW = (2, 6)     # << decode_block: boundary leaves segments dark
CH_STAGE = 32           # staging-ring capacity for the in-segment engine

# pressure scenario (optimistic admission + preemption vs worst-case).
# Pool sized at half the aggregate worst-case page demand: worst-case
# admission can only seat pool/worst_case_per_req slots at a time, while
# most requests finish well short of max_new and never touch the margin.
PR_SLOTS = 8
PR_PAGE = 8
PR_MAX_LEN = 64
PR_N_REQS = 32
PR_PROMPT = (6, 13)
PR_MAX_NEW = 24
PR_SLO_FACTOR = 1.5     # slo_i = 1.5x the request's uncontended latency

# shared-prefix scenario (prefix cache vs plain paged). A few long
# templates (system prompts) fan out into many requests with short
# unique suffixes: the cache serves every template page from the pool
# after its first computation, so the prefill work per request collapses
# to the suffix.
SP_SLOTS = 8
SP_PAGE = 8
SP_MAX_LEN = 64
SP_N_REQS = 32
SP_TEMPLATES = 4
SP_TPL_LEN = 24         # 3 full pages of sharable prefix per template
SP_SUFFIX = (3, 7)      # unique tail per request
SP_MAX_NEW = (4, 9)
SP_STREAMS = 2          # second stream re-hits the drained (cached) pages

# wall_stream scenario (wall-clock runtime: TTFT vs completion latency).
# max_new >> decode_block so a request spans several segments and the
# first streamed chunk lands well before the final token.
WS_MAX_LEN = 64
WS_DECODE_BLOCK = 4
WS_PROMPT = (4, 13)
WS_MAX_NEW = 24         # 6 segments at decode_block=4
WS_MAX_NEW_TINY = 12

# long-tail scenario (paged vs contiguous capacity)
LT_MAX_LEN = 128        # worst-case context a slot must provision for
LT_PAGE = 16
LT_CONTIG_SLOTS = 4     # pool = 4 * 128 = 512 positions = 32 pages
LT_PAGED_SLOTS = 16     # same pool, 4x slots: length-proportional pages
LT_N_REQS = 48
LT_LONG_EVERY = 8       # 1 in 8 requests is a near-max_len prompt


def _stream(cfg, seed: int):
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(4, 29))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(4, MAX_NEW + 1)))
            for i in range(N_REQS)]


def _tokens(reqs) -> int:
    return sum(r.max_new_tokens for r in reqs)


def _drive(engine, cfg) -> dict:
    res = {}
    reqs = _stream(cfg, 0)
    t0 = time.perf_counter()
    engine.serve(reqs)
    dt = time.perf_counter() - t0
    res["cold_s"] = dt
    res["toks_per_s_cold"] = _tokens(reqs) / dt

    total, t0 = 0, time.perf_counter()
    for seed in range(1, 1 + STEADY_STREAMS):
        reqs = _stream(cfg, seed)
        total += _tokens(reqs)
        engine.serve(reqs)
    dt = time.perf_counter() - t0
    res["steady_s"] = dt
    res["toks_per_s_steady"] = total / dt

    reqs = _stream(cfg, 0)
    t0 = time.perf_counter()
    engine.serve(reqs)
    dt = time.perf_counter() - t0
    res["warm_repeat_s"] = dt
    res["toks_per_s_warm_repeat"] = _tokens(reqs) / dt
    res.update({k: v for k, v in engine.stats.items()
                if k.endswith("_traces")})
    return res


def _long_tail_stream(cfg, seed: int, n_reqs: int, max_len: int,
                      max_new: int, long_every: int):
    """Mostly-short prompts with a rare near-max_len tail."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_reqs):
        if i % long_every == long_every - 1:
            plen = max_len - max_new          # near-max_len straggler
        else:
            plen = int(rng.integers(4, 13))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, max_new + 1))))
    return reqs


def _drive_long_tail(engine, reqs) -> dict:
    engine.warmup(prompt_lens=[len(r.prompt) for r in reqs])
    t0 = time.perf_counter()
    engine.serve(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in reqs)
    return {
        "wall_s": dt,
        "toks_per_s": toks / dt,
        "peak_concurrent_slots": engine.stats["peak_concurrency"],
        "chunk_admits": engine.stats["chunk_admits"],
        "p99_latency_s": float(np.quantile(
            [r.latency for r in reqs], 0.99)),
        "mean_latency_s": float(np.mean([r.latency for r in reqs])),
    }


def run_long_tail(verbose: bool = True, tiny: bool = False) -> List[Row]:
    """Paged vs max-shape slot capacity on a long-tail prompt stream."""
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    max_len = 64 if tiny else LT_MAX_LEN
    contig_slots = 2 if tiny else LT_CONTIG_SLOTS
    paged_slots = 8 if tiny else LT_PAGED_SLOTS
    n_reqs = 12 if tiny else LT_N_REQS
    max_new = 8
    page = 8 if tiny else LT_PAGE
    pool_positions = contig_slots * max_len
    n_pages = pool_positions // page

    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def stream():
        return _long_tail_stream(cfg, 0, n_reqs, max_len, max_new,
                                 LT_LONG_EVERY)

    contig = _drive_long_tail(
        ServingEngine(model, params, max_batch=contig_slots,
                      max_len=max_len, decode_block=16), stream())
    paged = _drive_long_tail(
        ServingEngine(model, params, max_batch=paged_slots,
                      max_len=max_len, decode_block=16, page_size=page,
                      n_pages=n_pages), stream())
    # chunked prefill trades prompt-side FLOP efficiency (token-at-a-time
    # through the decode loop) for zero prefill stalls in front of
    # in-flight decodes — on a memory-bound accelerator the trade is
    # free; on this host-CPU harness it shows up as tok/s
    chunked = _drive_long_tail(
        ServingEngine(model, params, max_batch=paged_slots,
                      max_len=max_len, decode_block=16, page_size=page,
                      n_pages=n_pages, chunk_threshold=16), stream())

    out = {
        "workload": {
            "n_requests": n_reqs, "max_len": max_len,
            "short_prompts": "4..12", "long_prompt": max_len - max_new,
            "long_every": LT_LONG_EVERY, "max_new": f"4..{max_new}",
            "arch": cfg.name, "backend": jax.default_backend(),
            "tiny": tiny,
        },
        "pool": {"positions": pool_positions, "page_size": page,
                 "n_pages": n_pages,
                 "max_batch_contiguous": pool_positions // max_len,
                 "paged_slots": paged_slots},
        "contiguous": contig,
        "paged": paged,
        "paged_chunked": chunked,
        "speedup_toks": paged["toks_per_s"] / contig["toks_per_s"],
        "concurrency_gain": (paged["peak_concurrent_slots"]
                             / max(contig["peak_concurrent_slots"], 1)),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine_paged.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        for name, r in (("contiguous", contig), ("paged", paged),
                        ("paged_chunked", chunked)):
            print(f"# {name}: {r['toks_per_s']:.0f} tok/s | "
                  f"peak {r['peak_concurrent_slots']} slots | "
                  f"{r['chunk_admits']} chunked admits | "
                  f"mean latency {r['mean_latency_s']*1e3:.0f} ms")
        print(f"# same pool ({pool_positions} positions): paged admits "
              f"{paged['peak_concurrent_slots']} concurrent vs "
              f"{out['pool']['max_batch_contiguous']} max-shape slots "
              f"-> {path}")
    return [
        ("engine_longtail_tok_s_contig", contig["toks_per_s"], "baseline"),
        ("engine_longtail_tok_s_paged", paged["toks_per_s"],
         f"{out['speedup_toks']:.2f}x"),
        ("engine_longtail_peak_slots_paged",
         float(paged["peak_concurrent_slots"]),
         f"{out['concurrency_gain']:.1f}x concurrency"),
    ]


def _shared_prefix_stream(cfg, seed: int, n_reqs: int, n_templates: int):
    """Requests fanning out from a few long shared templates."""
    from repro.serving.engine import Request
    t_rng = np.random.default_rng(1234)     # templates fixed across seeds
    tpls = [t_rng.integers(0, cfg.vocab, size=SP_TPL_LEN).astype(np.int32)
            for _ in range(n_templates)]
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_reqs):
        sfx = rng.integers(0, cfg.vocab,
                           size=int(rng.integers(*SP_SUFFIX))
                           ).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([tpls[i % n_templates], sfx]),
            max_new_tokens=int(rng.integers(*SP_MAX_NEW))))
    return reqs


def _drive_shared_prefix(engine, streams) -> dict:
    engine.warmup(prompt_lens=[len(r.prompt)
                               for reqs in streams for r in reqs])
    total_new, total_prompt = 0, 0
    t0 = time.perf_counter()
    for reqs in streams:
        engine.serve(reqs)
        total_new += sum(len(r.tokens) for r in reqs)
        total_prompt += sum(len(r.prompt) for r in reqs)
    dt = time.perf_counter() - t0
    s = engine.stats
    n_reqs = sum(len(reqs) for reqs in streams)
    skipped = s.get("prefix_tokens_skipped", 0)
    return {
        "wall_s": dt,
        "toks_per_s": total_new / dt,
        "prompt_tokens": total_prompt,
        "prefill_tokens_skipped": skipped,
        # prefill tokens the engine actually had to compute, vs a
        # cache-less engine computing all of them
        "prefill_goodput_win": total_prompt / max(total_prompt - skipped,
                                                  1),
        "prefix_hits": s.get("prefix_hits", 0),
        "hit_rate": s.get("prefix_hits", 0) / n_reqs,
        "prefix_pages_reused": s.get("prefix_pages_reused", 0),
        "cow_copies": s.get("cow_copies", 0),
        "evictions": s.get("evictions", 0),
        "chunk_admits": s["chunk_admits"],
        "mean_latency_s": float(np.mean(
            [r.latency for reqs in streams for r in reqs])),
    }


def run_shared_prefix(verbose: bool = True, tiny: bool = False) -> List[Row]:
    """Prefix cache (COW page sharing) vs plain paged on a template fan-out
    workload -> BENCH_engine_shared_prefix.json."""
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    slots = 4 if tiny else SP_SLOTS
    n_reqs = 8 if tiny else SP_N_REQS
    n_templates = 2 if tiny else SP_TEMPLATES
    page = SP_PAGE
    n_pages = slots * SP_MAX_LEN // page

    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(max_batch=slots, max_len=SP_MAX_LEN, decode_block=8,
              page_size=page, n_pages=n_pages, chunk_threshold=16)

    def streams():
        return [_shared_prefix_stream(cfg, seed, n_reqs, n_templates)
                for seed in range(SP_STREAMS)]

    base_streams = streams()
    base = _drive_shared_prefix(ServingEngine(model, params, **kw),
                                base_streams)
    pref_streams = streams()
    pref = _drive_shared_prefix(
        ServingEngine(model, params, prefix_cache=True, **kw),
        pref_streams)

    outputs_match = all(
        bool(np.array_equal(a.tokens, b.tokens))
        for sa, sb in zip(base_streams, pref_streams)
        for a, b in zip(sa, sb))
    out = {
        "workload": {
            "n_requests": n_reqs * SP_STREAMS, "slots": slots,
            "templates": n_templates, "template_len": SP_TPL_LEN,
            "suffix_len": f"{SP_SUFFIX[0]}..{SP_SUFFIX[1] - 1}",
            "max_new": f"{SP_MAX_NEW[0]}..{SP_MAX_NEW[1] - 1}",
            "streams": SP_STREAMS, "arch": cfg.name,
            "backend": jax.default_backend(), "tiny": tiny,
        },
        "pool": {"page_size": page, "n_pages": n_pages},
        "paged_no_cache": base,
        "paged_prefix_cache": pref,
        "outputs_match": outputs_match,
        "speedup_toks": pref["toks_per_s"] / base["toks_per_s"],
        "prefill_goodput_win": pref["prefill_goodput_win"],
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine_shared_prefix.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        for name, r in (("paged_no_cache", base),
                        ("paged_prefix_cache", pref)):
            print(f"# {name}: {r['toks_per_s']:.0f} tok/s | "
                  f"{r['prefill_tokens_skipped']}/{r['prompt_tokens']} "
                  f"prefill tokens skipped | hit rate {r['hit_rate']:.2f} "
                  f"| {r['cow_copies']} COW | {r['evictions']} evictions")
        print(f"# prefix cache: {out['prefill_goodput_win']:.2f}x prefill "
              f"goodput, {out['speedup_toks']:.2f}x tok/s, outputs "
              f"bit-identical: {outputs_match} -> {path}")
    return [
        ("engine_shared_prefix_tok_s_paged", base["toks_per_s"],
         "baseline"),
        ("engine_shared_prefix_tok_s_cached", pref["toks_per_s"],
         f"{out['speedup_toks']:.2f}x"),
        ("engine_shared_prefix_goodput_win", pref["prefill_goodput_win"],
         f"hit rate {pref['hit_rate']:.2f}, "
         f"bit-identical={outputs_match}"),
    ]


def _pressure_stream(cfg, seed: int, n_reqs: int, max_new: int):
    """Burst of mid-length prompts with full decode budgets."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(
                                            PR_PROMPT[0], PR_PROMPT[1] + 1))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n_reqs)]


def _drive_pressure(engine, reqs, slos=None) -> dict:
    engine.warmup(prompt_lens=sorted({len(r.prompt) for r in reqs}))
    if slos is not None:
        for r, s in zip(reqs, slos):
            r.slo = s
    t0 = time.perf_counter()
    engine.serve(reqs)
    wall = time.perf_counter() - t0
    viol = (sum(1 for r in reqs if r.slo is not None and r.latency > r.slo)
            / len(reqs)) if slos is not None else 0.0
    s = engine.stats
    return {
        "wall_s": wall,
        "goodput_req_s": len(reqs) / wall,
        "violation_rate": viol,
        "peak_concurrency": s["peak_concurrency"],
        "preemptions": s["preemptions"],
        "preempt_readmits": s["preempt_readmits"],
        "pressure_stalls": s["pressure_stalls"],
        "mean_latency_s": float(np.mean([r.latency for r in reqs])),
        "p99_latency_s": float(np.quantile(
            [r.latency for r in reqs], 0.99)),
    }


def run_pressure(verbose: bool = True, tiny: bool = False) -> List[Row]:
    """Optimistic admission + preemption vs worst-case on a 50% pool."""
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    slots = 4 if tiny else PR_SLOTS
    n_reqs = 10 if tiny else PR_N_REQS
    max_new = 12 if tiny else PR_MAX_NEW
    page = PR_PAGE
    # worst-case pages one slot can pin: prompt_max + max_new - 1 positions
    worst_pages = -(-(PR_PROMPT[1] + max_new - 1) // page)
    n_pages = slots * worst_pages // 2          # 50% of aggregate demand

    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(max_batch=slots, max_len=PR_MAX_LEN, decode_block=8,
              min_bucket=4, page_size=page)

    # uncontended reference: full-capacity pool, worst-case admission.
    # Sets the output ground truth and each request's solo latency, from
    # which the per-request SLOs for the pressure runs are derived.
    ref_reqs = _pressure_stream(cfg, 0, n_reqs, max_new)
    ref = _drive_pressure(
        ServingEngine(model, params, n_pages=slots * worst_pages, **kw),
        ref_reqs)
    slos = [PR_SLO_FACTOR * r.latency for r in ref_reqs]

    wc_reqs = _pressure_stream(cfg, 0, n_reqs, max_new)
    wc = _drive_pressure(
        ServingEngine(model, params, n_pages=n_pages,
                      admission="worstcase", **kw), wc_reqs, slos)
    opt_reqs = _pressure_stream(cfg, 0, n_reqs, max_new)
    opt = _drive_pressure(
        ServingEngine(model, params, n_pages=n_pages,
                      admission="optimistic", **kw), opt_reqs, slos)

    outputs_match = all(
        len(a.tokens) == len(b.tokens)
        and bool(np.array_equal(a.tokens, b.tokens))
        for a, b in zip(ref_reqs, opt_reqs))
    out = {
        "workload": {
            "n_requests": n_reqs, "slots": slots,
            "prompt_len": f"{PR_PROMPT[0]}..{PR_PROMPT[1]}",
            "max_new": max_new, "slo_factor": PR_SLO_FACTOR,
            "arch": cfg.name, "backend": jax.default_backend(),
            "tiny": tiny,
        },
        "pool": {"page_size": page, "n_pages": n_pages,
                 "worst_case_pages_per_slot": worst_pages,
                 "worst_case_demand_pages": slots * worst_pages},
        "reference_big_pool": ref,
        "worstcase": wc,
        "optimistic": opt,
        "outputs_match_reference": outputs_match,
        "goodput_gain": opt["goodput_req_s"] / wc["goodput_req_s"],
        "concurrency_gain": (opt["peak_concurrency"]
                             / max(wc["peak_concurrency"], 1)),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine_pressure.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        for name, r in (("worstcase", wc), ("optimistic", opt)):
            print(f"# {name}: {r['goodput_req_s']:.1f} req/s | "
                  f"viol {r['violation_rate']:.2f} | "
                  f"peak {r['peak_concurrency']} slots | "
                  f"{r['preemptions']} preempts / "
                  f"{r['pressure_stalls']} stalls")
        print(f"# optimistic on a 50% pool ({n_pages} pages): "
              f"{out['goodput_gain']:.2f}x goodput, "
              f"{out['concurrency_gain']:.1f}x concurrency, "
              f"outputs bit-identical to the uncontended reference: "
              f"{outputs_match} -> {path}")
    return [
        ("engine_pressure_goodput_worstcase", wc["goodput_req_s"],
         "baseline"),
        ("engine_pressure_goodput_optimistic", opt["goodput_req_s"],
         f"{out['goodput_gain']:.2f}x"),
        ("engine_pressure_peak_slots_optimistic",
         float(opt["peak_concurrency"]),
         f"{out['concurrency_gain']:.1f}x concurrency, "
         f"bit-identical={outputs_match}"),
    ]


def _churn_stream(cfg, seed: int, n_reqs: int):
    """Short prompts, mixed short decode budgets: slots free mid-segment."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(
                                            CH_PROMPT[0], CH_PROMPT[1] + 1))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(CH_MAX_NEW[0],
                                                    CH_MAX_NEW[1] + 1)))
            for i in range(n_reqs)]


def _drive_churn(engine, reqs, arrivals) -> dict:
    """Open-loop: submit each request at its Poisson arrival offset, step
    the engine whenever it has work, and report goodput / latency / queue
    delay / segment-occupancy figures."""
    engine.warmup(prompt_lens=sorted({len(r.prompt) for r in reqs}))
    n = len(reqs)
    t0 = time.perf_counter()
    i = 0
    while i < n or engine.busy:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            reqs[i].arrival = t0 + arrivals[i]
            engine.submit(reqs[i])
            i += 1
        if engine.busy:
            engine.step()
        elif i < n:
            time.sleep(max(arrivals[i] - (time.perf_counter() - t0), 0.0))
    while engine.busy:
        engine.step()
    engine.drain_completions()
    wall = time.perf_counter() - t0
    lats = np.asarray([r.latency for r in reqs])
    qd = np.asarray([r.admitted - r.arrival for r in reqs])
    s = engine.stats
    return {
        "wall_s": wall,
        "goodput_req_s": n / wall,
        "segments_per_request": s["decode_dispatches"] / n,
        "prefill_dispatches": s["prefill_dispatches"],
        "decode_dispatches": s["decode_dispatches"],
        "inseg_admissions": s["inseg_admissions"],
        "slot_busy_frac": engine.occupancy["slot_busy_frac"],
        "p50_latency_s": float(np.quantile(lats, 0.5)),
        "p99_latency_s": float(np.quantile(lats, 0.99)),
        "p99_queue_delay_s": float(np.quantile(qd, 0.99)),
        "mean_latency_s": float(np.mean(lats)),
    }


def run_churn(verbose: bool = True, tiny: bool = False) -> List[Row]:
    """In-segment admission vs boundary-only under short-request churn."""
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    slots = 2 if tiny else CH_SLOTS
    n_reqs = 16 if tiny else CH_N_REQS
    decode_block = 32 if tiny else CH_DECODE_BLOCK
    stage = 8 if tiny else CH_STAGE

    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(max_batch=slots, max_len=CH_MAX_LEN,
              decode_block=decode_block)

    # calibrate the arrival rate to ~2x the boundary engine's drain rate:
    # the queue stays deep (bursty overload), so slots freed mid-segment
    # always have a successor waiting — the regime in-segment admission
    # targets
    calib = ServingEngine(model, params, **kw)
    cal = _churn_stream(cfg, 99, max(slots * 2, 4))
    calib.warmup(prompt_lens=sorted({len(r.prompt) for r in cal}))
    t0 = time.perf_counter()
    calib.serve(cal)
    rate = 2.0 * len(cal) / (time.perf_counter() - t0)
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_reqs))

    boundary = _drive_churn(
        ServingEngine(model, params, stage_slots=0, **kw),
        _churn_stream(cfg, 0, n_reqs), arrivals)
    inseg = _drive_churn(
        ServingEngine(model, params, stage_slots=stage, **kw),
        _churn_stream(cfg, 0, n_reqs), arrivals)

    out = {
        "workload": {
            "n_requests": n_reqs, "slots": slots,
            "max_len": CH_MAX_LEN, "decode_block": decode_block,
            "stage_slots": stage,
            "prompt_len": f"{CH_PROMPT[0]}..{CH_PROMPT[1]}",
            "max_new": f"{CH_MAX_NEW[0]}..{CH_MAX_NEW[1]}",
            "poisson_rate_req_s": rate, "arch": cfg.name,
            "backend": jax.default_backend(), "tiny": tiny,
        },
        "boundary_only": boundary,
        "in_segment": inseg,
        "speedup_goodput": (inseg["goodput_req_s"]
                            / boundary["goodput_req_s"]),
        "segments_per_request_ratio": (boundary["segments_per_request"]
                                       / inseg["segments_per_request"]),
        "p99_queue_delay_ratio": (boundary["p99_queue_delay_s"]
                                  / max(inseg["p99_queue_delay_s"], 1e-9)),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine_churn.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        for name, r in (("boundary_only", boundary), ("in_segment", inseg)):
            print(f"# {name}: {r['goodput_req_s']:.1f} req/s | "
                  f"{r['segments_per_request']:.2f} segments/req | "
                  f"occupancy {r['slot_busy_frac']:.2f} | "
                  f"p99 queue delay {r['p99_queue_delay_s']*1e3:.0f} ms | "
                  f"{r['inseg_admissions']} in-segment admits")
        print(f"# in-segment admission: {out['speedup_goodput']:.2f}x "
              f"goodput, {out['segments_per_request_ratio']:.2f}x fewer "
              f"segments/req, {out['p99_queue_delay_ratio']:.2f}x lower "
              f"p99 queue delay -> {path}")
    return [
        ("engine_churn_goodput_boundary", boundary["goodput_req_s"],
         "baseline"),
        ("engine_churn_goodput_inseg", inseg["goodput_req_s"],
         f"{out['speedup_goodput']:.2f}x"),
        ("engine_churn_p99_queue_delay_inseg",
         inseg["p99_queue_delay_s"],
         f"{out['p99_queue_delay_ratio']:.2f}x lower"),
    ]


def run_wall_stream(verbose: bool = True, tiny: bool = False) -> List[Row]:
    """Wall-clock serving runtime: TTFT vs completion latency at equal
    goodput, end to end through the control plane (master -> worker ->
    threaded engine stepper -> streamed tokens)."""
    from repro.configs.registry import ARCHS
    from repro.core.api import QueryPayload, QuerySpec
    from repro.serving.executor import EngineExecutorConfig
    from repro.serving.runtime import ServingRuntime
    from repro.sim.cluster import make_cluster

    arch = "llama3.2-1b"
    n_reqs = 8 if tiny else 32
    max_new = WS_MAX_NEW_TINY if tiny else WS_MAX_NEW
    ecfg = EngineExecutorConfig(max_batch=4, max_len=WS_MAX_LEN,
                                decode_block=WS_DECODE_BLOCK)
    c = make_cluster(n_accel=1, n_cpu=0, archs=[ARCHS[arch]],
                     backend="real", clock="wall", engine_cfg=ecfg)
    rt = ServingRuntime(c)
    rng = np.random.default_rng(0)
    vocab = ARCHS[arch].reduced().vocab

    def spec():
        prompt = rng.integers(
            0, vocab,
            size=int(rng.integers(WS_PROMPT[0], WS_PROMPT[1] + 1))
        ).astype(np.int32)
        return QuerySpec.arch(
            arch, latency_ms=120_000.0,
            payload=QueryPayload.of([prompt], max_new_tokens=max_new))

    # warmup outside the measured window (engine build + XLA compiles),
    # then probe one warm query to calibrate the Poisson rate at ~2
    # concurrent requests in the system
    rt.submit(spec()).result(timeout=600.0)
    t_probe = time.perf_counter()
    rt.submit(spec()).result(timeout=600.0)
    probe = time.perf_counter() - t_probe
    rate = 2.0 / max(probe, 1e-3)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_reqs))

    handles = []
    t0 = time.perf_counter()
    for a in arrivals:
        wait = t0 + a - time.perf_counter()
        if wait > 0.0:
            time.sleep(wait)
        handles.append(rt.submit(spec()))   # client thread -> scheduler
    results = [h.result(timeout=600.0) for h in handles]
    wall = time.perf_counter() - t0
    rt.shutdown(drain=True)

    ok = [r for r in results if r.ok]
    ttfts = [h.ttft for h in handles if h.ttft is not None]
    lats = [r.latency for r in ok]
    chunks = [len(h.chunks) for h in handles]
    ttft_p50 = float(np.quantile(ttfts, 0.5))
    ttft_p99 = float(np.quantile(ttfts, 0.99))
    lat_p50 = float(np.quantile(lats, 0.5))
    lat_p99 = float(np.quantile(lats, 0.99))
    out = {
        "workload": {
            "n_requests": n_reqs, "arch": arch,
            "prompt_len": f"{WS_PROMPT[0]}..{WS_PROMPT[1]}",
            "max_new": max_new, "decode_block": WS_DECODE_BLOCK,
            "max_len": WS_MAX_LEN, "poisson_rate_req_s": float(rate),
            "backend": jax.default_backend(), "tiny": tiny,
        },
        "completed_ok": len(ok),
        "goodput_req_s": len(ok) / wall,
        "wall_s": wall,
        "streamed_chunks_per_query_mean": float(np.mean(chunks)),
        "ttft_p50_s": ttft_p50, "ttft_p99_s": ttft_p99,
        "completion_p50_s": lat_p50, "completion_p99_s": lat_p99,
        # the headline: how much sooner the first tokens reach the client
        # than the full answer, on the same served stream (equal goodput
        # by construction)
        "ttft_speedup_p50": lat_p50 / max(ttft_p50, 1e-9),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine_wall.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        print(f"# wall_stream: {len(ok)}/{n_reqs} ok | "
              f"{out['goodput_req_s']:.2f} req/s | "
              f"{out['streamed_chunks_per_query_mean']:.1f} chunks/query | "
              f"TTFT p50 {ttft_p50*1e3:.0f} ms vs completion p50 "
              f"{lat_p50*1e3:.0f} ms ({out['ttft_speedup_p50']:.2f}x "
              f"sooner) -> {path}")
    return [
        ("engine_wall_ttft_p50_s", ttft_p50,
         f"{out['ttft_speedup_p50']:.2f}x before completion p50"),
        ("engine_wall_completion_p50_s", lat_p50, "same stream"),
        ("engine_wall_goodput", out["goodput_req_s"],
         f"{len(ok)} served on the wall clock"),
    ]


def run(verbose: bool = True) -> List[Row]:
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine, WaveEngine

    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    wave = _drive(WaveEngine(model, params, max_batch=SLOTS), cfg)
    cont = _drive(ServingEngine(model, params, max_batch=SLOTS,
                                max_len=MAX_LEN, decode_block=DECODE_BLOCK),
                  cfg)

    out = {
        "workload": {"n_requests_per_stream": N_REQS, "slots": SLOTS,
                     "prompt_len": "4..28", "max_new": f"4..{MAX_NEW}",
                     "steady_streams": STEADY_STREAMS, "arch": cfg.name,
                     "backend": jax.default_backend()},
        "seed_wave": wave,
        "continuous": cont,
        "speedup_steady": (cont["toks_per_s_steady"]
                           / wave["toks_per_s_steady"]),
        "speedup_cold": cont["toks_per_s_cold"] / wave["toks_per_s_cold"],
        "speedup_warm_repeat": (cont["toks_per_s_warm_repeat"]
                                / wave["toks_per_s_warm_repeat"]),
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        for name, r in (("seed_wave", wave), ("continuous", cont)):
            print(f"# {name}: cold {r['toks_per_s_cold']:.0f} tok/s | "
                  f"steady {r['toks_per_s_steady']:.0f} tok/s | "
                  f"warm-repeat {r['toks_per_s_warm_repeat']:.0f} tok/s | "
                  f"traces prefill={r['prefill_traces']} "
                  f"decode={r['decode_traces']}")
        print(f"# speedup: steady {out['speedup_steady']:.2f}x, "
              f"warm-repeat {out['speedup_warm_repeat']:.2f}x, "
              f"cold {out['speedup_cold']:.2f}x -> {path}")
    return [
        ("engine_steady_tok_s_wave", wave["toks_per_s_steady"], "baseline"),
        ("engine_steady_tok_s_cont", cont["toks_per_s_steady"],
         f"{out['speedup_steady']:.2f}x"),
        ("engine_warm_repeat_tok_s_cont", cont["toks_per_s_warm_repeat"],
         f"{out['speedup_warm_repeat']:.2f}x"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario",
                    choices=["classic", "long_tail", "churn", "pressure",
                             "shared_prefix", "wall_stream", "all"],
                    default="all")
    ap.add_argument("--tiny", action="store_true",
                    help="small shapes for CI smoke runs")
    args = ap.parse_args()
    if args.scenario in ("classic", "all"):
        run()
    if args.scenario in ("long_tail", "all"):
        run_long_tail(tiny=args.tiny)
    if args.scenario in ("churn", "all"):
        run_churn(tiny=args.tiny)
    if args.scenario in ("pressure", "all"):
        run_pressure(tiny=args.tiny)
    if args.scenario in ("shared_prefix", "all"):
        run_shared_prefix(tiny=args.tiny)
    if args.scenario in ("wall_stream", "all"):
        run_wall_stream(tiny=args.tiny)
