"""End-to-end latency: open-loop vs closed-loop serving on a real engine.

Replays the same mixed Poisson request stream (mixed prompt lengths and
decode lengths) against two identical continuous-batching engines driven
two ways:

* ``closed`` — the PR-1 loop: requests that arrive while ``serve()`` is
  running wait for the current batch to fully drain, then the backlog is
  served as the next batch. Admission only happens at serve() boundaries.
* ``open``   — the step-driven core: arrivals are ``submit()``-ed as they
  occur and join at the next decode-segment boundary (``step()``), without
  waiting for in-flight requests to finish.

Per-request latency is measured from the request's (replayed) arrival
time, so the closed loop pays its batch-drain queueing delay and the open
loop only pays segment granularity. The arrival rate is calibrated to the
engine's measured capacity (offered load ~ capacity), where the difference
is starkest. Both engines are warmed up first; no compile time is inside
the measured window.

Writes ``BENCH_e2e_real.json`` at the repo root.

Run:  PYTHONPATH=src python benchmarks/fig_e2e_real.py
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Tuple

import jax
import numpy as np

Row = Tuple[str, float, str]

N_REQS = 24
SLOTS = 4
MAX_LEN = 64
DECODE_BLOCK = 4
PROMPT_RANGE = (4, 17)
MAX_NEW_RANGE = (4, 25)
UTILIZATION = 1.0      # offered load as a fraction of measured capacity


def _stream(cfg, seed: int, n: int = N_REQS):
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(*PROMPT_RANGE))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(*MAX_NEW_RANGE)))
            for i in range(n)]


def _arrival_offsets(rate: float, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _drive_open(eng, reqs, offsets) -> List:
    """Submit each request at its arrival offset; step whenever busy."""
    done: List = []
    t0 = time.perf_counter()
    i = 0
    while len(done) < len(reqs):
        now = time.perf_counter() - t0
        while i < len(reqs) and offsets[i] <= now:
            reqs[i].arrival = t0 + offsets[i]
            eng.submit(reqs[i])
            i += 1
        if eng.busy:
            eng.step()
            done.extend(eng.drain_completions())
        elif i < len(reqs):
            time.sleep(max(offsets[i] - (time.perf_counter() - t0), 0.0))
    return done


def _drive_closed(eng, reqs, offsets) -> List:
    """PR-1 loop: arrivals during serve() wait for the batch to drain."""
    done: List = []
    t0 = time.perf_counter()
    i = 0
    while len(done) < len(reqs):
        now = time.perf_counter() - t0
        batch = []
        while i < len(reqs) and offsets[i] <= now:
            reqs[i].arrival = t0 + offsets[i]
            batch.append(reqs[i])
            i += 1
        if batch:
            done.extend(eng.serve(batch))
        elif i < len(reqs):
            time.sleep(max(offsets[i] - (time.perf_counter() - t0), 0.0))
    return done


def _summary(reqs, wall: float) -> dict:
    lats = np.asarray([r.latency for r in reqs]) * 1e3
    toks = sum(len(r.tokens) for r in reqs)
    return {
        "p50_ms": float(np.percentile(lats, 50)),
        "p99_ms": float(np.percentile(lats, 99)),
        "mean_ms": float(lats.mean()),
        "makespan_s": wall,
        "toks_per_s": toks / wall,
    }


def run(verbose: bool = True) -> List[Row]:
    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.serving.engine import ServingEngine

    cfg = ARCHS["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def fresh_engine():
        eng = ServingEngine(model, params, max_batch=SLOTS, max_len=MAX_LEN,
                            decode_block=DECODE_BLOCK)
        eng.warmup(prompt_lens=list(range(*PROMPT_RANGE)))
        return eng

    # calibrate: serve a probe stream to measure per-request capacity
    probe_eng = fresh_engine()
    probe = _stream(cfg, seed=99)
    t0 = time.perf_counter()
    probe_eng.serve(probe)
    cap = len(probe) / (time.perf_counter() - t0)   # reqs/s at saturation
    rate = UTILIZATION * cap
    offsets = _arrival_offsets(rate, N_REQS, seed=7)

    results = {}
    for mode, drive in (("closed", _drive_closed), ("open", _drive_open)):
        eng = fresh_engine()
        reqs = _stream(cfg, seed=0)
        t0 = time.perf_counter()
        served = drive(eng, reqs, offsets)
        wall = time.perf_counter() - t0
        results[mode] = _summary(served, wall)
        results[mode]["decode_dispatches"] = eng.stats["decode_dispatches"]

    out = {
        "workload": {"n_requests": N_REQS, "slots": SLOTS,
                     "prompt_len": f"{PROMPT_RANGE[0]}..{PROMPT_RANGE[1]-1}",
                     "max_new": f"{MAX_NEW_RANGE[0]}..{MAX_NEW_RANGE[1]-1}",
                     "rate_qps": rate, "arch": cfg.name,
                     "backend": jax.default_backend()},
        "closed_loop": results["closed"],
        "open_loop": results["open"],
        "p50_speedup": results["closed"]["p50_ms"] / results["open"]["p50_ms"],
        "p99_speedup": results["closed"]["p99_ms"] / results["open"]["p99_ms"],
    }
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_e2e_real.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    if verbose:
        for mode in ("closed", "open"):
            r = results[mode]
            print(f"# {mode}: p50 {r['p50_ms']:.1f}ms | "
                  f"p99 {r['p99_ms']:.1f}ms | mean {r['mean_ms']:.1f}ms | "
                  f"{r['toks_per_s']:.0f} tok/s")
        print(f"# open-loop latency: p50 {out['p50_speedup']:.2f}x, "
              f"p99 {out['p99_speedup']:.2f}x lower -> {path}")
    return [
        ("e2e_real_p99_ms_closed", results["closed"]["p99_ms"], "baseline"),
        ("e2e_real_p99_ms_open", results["open"]["p99_ms"],
         f"{out['p99_speedup']:.2f}x"),
        ("e2e_real_tok_s_open", results["open"]["toks_per_s"], ""),
    ]


if __name__ == "__main__":
    run()
