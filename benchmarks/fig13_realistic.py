"""Figs. 12-14: the end-to-end realistic workload.

Zipf popularity (20% of archs get 80% of load), Poisson arrivals stepping
50 -> 500 q/s, INFaaS vs STATIC vs INDV, plus INFaaS w/offline. Paper
headline: 2x throughput, 3x fewer SLO violations, ~6x higher accelerator
utilization at similar CPU utilization.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.api import QuerySpec
from repro.core.master import MasterConfig
from repro.sim.cluster import make_cluster, serving_archs
from repro.sim.workload import (popularity_split, poisson_arrivals,
                                step_rate)
from benchmarks.common import (Row, UtilTracker, baseline_variant,
                               cluster_cost, steady_metrics)

LEVELS = [(40.0, r) for r in (50.0, 162.0, 275.0, 387.0, 500.0)]
T_END = sum(d for d, _ in LEVELS)


def _drive(c, infaas_mode: bool, with_offline: bool, seed: int):
    archs = [a.name for a in serving_archs()]
    # popularity: order by variant count (paper: top-20% by #variants)
    archs.sort(key=lambda a: -len(c.store.registry.archs[a].variants))
    split = popularity_split(archs)
    names = list(split.weights)
    probs = np.array([split.weights[a] for a in names])
    probs = probs / probs.sum()
    rng = np.random.default_rng(seed)
    chosen = {a: baseline_variant(c, a) for a in names}
    # SLO per arch: 3x the standalone latency of the baseline-chosen variant
    # (headroom for adaptive batching; paper sets it to the standalone avg)
    slos = {a: max(3.0 * chosen[a].profile.latency(1) * 1e3, 10.0)
            for a in names}

    def fire(t):
        a = names[rng.choice(len(names), p=probs)]
        if infaas_mode:
            c.api.submit(QuerySpec.arch(a, latency_ms=slos[a]))
        else:
            c.api.submit(QuerySpec.variant(chosen[a].name,
                                           latency_ms=slos[a]))

    tracker = UtilTracker(c, t_end=T_END)
    poisson_arrivals(c.loop, step_rate(LEVELS), fire, t_end=T_END, seed=seed)
    if with_offline:
        for _ in range(8):
            c.api.submit(QuerySpec.arch("llama3.2-1b", mode="offline",
                                        n_inputs=500))
    c.run_until(T_END + 30.0)
    m = steady_metrics(c.master.metrics, 0.0, T_END, warmup=20.0)
    m.update(tracker.summary())
    m["cost"] = cluster_cost(c, T_END)
    m["workers"] = sum(1 for w in c.store.workers.values() if w.alive)
    if with_offline:
        m["offline_done"] = float(sum(j.processed
                                      for j in c.master.offline_done))
    return m


def _static_cluster(preload: bool = True):
    cfg = MasterConfig(worker_autoscale=False)
    c = make_cluster(n_accel=8, n_cpu=16, autoscale=False, cfg=cfg)
    if preload:
        _preload(c)
    return c


def _preload(c):
    """STATIC/INDV: persist the user-chosen variant of every arch."""
    workers = list(c.master.workers.values())
    cpu_ws = [w for w in workers if "tpu-v5e-1" not in w.hardware]
    accel_ws = [w for w in workers if "tpu-v5e-1" in w.hardware]
    i = j = 0
    for a in [x.name for x in serving_archs()]:
        v = baseline_variant(c, a)
        if v.is_accel:
            accel_ws[j % len(accel_ws)].load_variant(v)
            j += 1
        else:
            cpu_ws[i % len(cpu_ws)].load_variant(v, replicas=2)
            i += 1
    c.run_until(8.0)


def run(verbose: bool = True) -> List[Row]:
    results: Dict[str, Dict[str, float]] = {}

    c = _static_cluster()
    results["STATIC"] = _drive(c, infaas_mode=False, with_offline=False,
                               seed=1)

    cfg = MasterConfig(allow_upgrade=False)
    c = make_cluster(n_accel=8, n_cpu=16, autoscale=True, cfg=cfg)
    _preload(c)
    results["INDV"] = _drive(c, infaas_mode=False, with_offline=False,
                             seed=2)

    c = make_cluster(n_accel=5, autoscale=True)
    c.master.autoscaler.cfg.max_workers = 24
    c.master.autoscaler.cfg.min_workers = 4   # paper: 5 -> 8 GPU workers
    results["INFaaS"] = _drive(c, infaas_mode=True, with_offline=False,
                               seed=3)

    c = make_cluster(n_accel=5, autoscale=True)
    c.master.autoscaler.cfg.max_workers = 24
    c.master.autoscaler.cfg.min_workers = 4
    results["INFaaS+off"] = _drive(c, infaas_mode=True, with_offline=True,
                                   seed=4)

    if verbose:
        for name, m in results.items():
            print(f"# fig13 {name:11s}: thr={m['throughput_qps']:7.1f} q/s "
                  f"viol={m['violation_rate']:.3f} p99={m['p99_ms']:.1f}ms "
                  f"cpu_util={m['cpu_util']:.2f} accel_util="
                  f"{m['accel_util']:.2f} workers={m['workers']:.0f}"
                  f"(peak {m['peak_workers']:.0f}) "
                  f"cost={m['cost']:.0f}"
                  + (f" offline={m.get('offline_done', 0):.0f}"
                     if "offline_done" in m else ""))
    inf, sta, ind = results["INFaaS"], results["STATIC"], results["INDV"]
    return [
        ("fig13_throughput_x_static",
         inf["throughput_qps"] / max(sta["throughput_qps"], 1e-9),
         f"paper_claims_2x"),
        ("fig13_viol_static_x_infaas",
         sta["violation_rate"] / max(inf["violation_rate"], 1e-3),
         "paper_claims_3x"),
        ("fig13_viol_indv_x_infaas",
         ind["violation_rate"] / max(inf["violation_rate"], 1e-3),
         "indv_worse"),
        ("fig14_accel_util_x_static",
         inf["accel_util"] / max(sta["accel_util"], 1e-3),
         "paper_claims_6x"),
        ("fig13_offline_images",
         results["INFaaS+off"].get("offline_done", 0.0),
         "of_4000_best_effort"),
        ("fig13_infaas_viol_rate", inf["violation_rate"], "absolute"),
    ]
