"""§Roofline table: read the dry-run JSONL manifest and print the per-cell
roofline terms (compute/memory/collective seconds, dominant term, useful-
FLOPs ratio). Source of EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import Row

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.jsonl")


def load_records(path: str = DEFAULT_PATH):
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return list(recs.values())


def run(verbose: bool = True, path: str = DEFAULT_PATH) -> List[Row]:
    recs = load_records(path)
    if not recs:
        print(f"# roofline: no dry-run manifest at {path} "
              "(run python -m repro.launch.dryrun --all --out "
              "dryrun_results.jsonl)")
        return [("roofline_cells", 0.0, "missing_manifest")]
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    if verbose:
        print("# arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_flops_ratio,peak_GiB")
        for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
            t = r["roofline"]
            print(f"#   {r['arch']},{r['shape']},{r['mesh']},"
                  f"{t['compute_s']:.4g},{t['memory_s']:.4g},"
                  f"{t['collective_s']:.4g},{t['dominant']},"
                  f"{t['useful_flops_ratio']:.3f},"
                  f"{r['bytes_per_device']['peak']/2**30:.2f}")
        for r in skipped:
            print(f"#   {r['arch']},{r['shape']},{r['mesh']},SKIPPED,"
                  f"{r['reason'][:60]}")
    dominant = {}
    for r in ok:
        dominant[r["roofline"]["dominant"]] = \
            dominant.get(r["roofline"]["dominant"], 0) + 1
    return [
        ("roofline_cells_ok", float(len(ok)), f"skipped_{len(skipped)}"
         f"_err_{len(err)}"),
        ("roofline_memory_bound_cells",
         float(dominant.get("memory", 0)), "dominant=memory"),
        ("roofline_compute_bound_cells",
         float(dominant.get("compute", 0)), "dominant=compute"),
        ("roofline_collective_bound_cells",
         float(dominant.get("collective", 0)), "dominant=collective"),
    ]
