"""Fig. 8: the linear latency model t(b) = m*b + c fitted from batches
{1,4,8} must predict latencies at larger batch sizes (R^2 check against the
full roofline curve at b in 1..64)."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.configs.registry import ARCHS
from repro.core import profiler as prof
from repro.sim import hardware as HW
from benchmarks.common import Row


def run(verbose: bool = True) -> List[Row]:
    r2s = []
    worst = ("", 1.0)
    for cfg in ARCHS.values():
        for hw_name in ("cpu-host", "tpu-v5e-1"):
            hw = HW.HARDWARE[hw_name]
            wl = prof.workload_model(cfg)
            for dtype in ("bf16",):
                wbytes = wl.n_total * prof.DTYPE_BYTES[dtype]
                batch_opt = 64
                p = prof.analytic_profile(cfg, hw, dtype, batch_opt)
                if p.peak_memory > hw.mem_capacity:
                    continue
                # evaluate inside the variant's own operating range
                bs = np.array([1, 2, 4, 8, 16, 24, 32, 48, 64])
                bs = bs[bs <= batch_opt]
                truth = np.array([
                    HW.roofline_latency(wl.flops(int(b)),
                                        wl.bytes_moved(int(b), wbytes), hw,
                                        0.6 if hw.kind == "accel" else 0.35)
                    + prof._dispatch_overhead(hw) for b in bs])
                pred = p.m * bs + p.c
                ss_res = float(np.sum((truth - pred) ** 2))
                ss_tot = float(np.sum((truth - truth.mean()) ** 2))
                r2 = 1.0 - ss_res / max(ss_tot, 1e-30)
                r2s.append(r2)
                if r2 < worst[1]:
                    worst = (f"{cfg.name}/{hw_name}", r2)
    if verbose:
        print(f"# fig8: linear-fit R^2 over {len(r2s)} (arch,hw) curves: "
              f"median={np.median(r2s):.4f} worst={worst[1]:.4f} ({worst[0]})")
    return [
        ("fig8_r2_median", float(np.median(r2s)), "linear_fit_quality"),
        ("fig8_r2_worst", float(worst[1]), worst[0]),
    ]
