"""Synthetic-but-deterministic data pipeline.

Generates a reproducible token stream per (seed, shard) with next-token
structure (a noisy linear-congruential language) so the training loss
actually decreases — enough signal to validate the training substrate
end-to-end without external datasets. Shards are indexed by data-parallel
rank, so restarts resume mid-stream deterministically via the step index.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    noise: float = 0.05


def _lcg_tokens(rng: np.random.Generator, n: int, vocab: int,
                noise: float) -> np.ndarray:
    """x_{t+1} = (a*x_t + c) % vocab, with occasional random resets."""
    a = 6364136223846793005 % vocab or 1
    c = 1442695040888963407 % vocab
    x = np.empty(n, np.int64)
    x[0] = rng.integers(0, vocab)
    noise_mask = rng.random(n) < noise
    rand = rng.integers(0, vocab, n)
    for t in range(1, n):
        x[t] = rand[t] if noise_mask[t] else (a * x[t - 1] + c) % vocab
    return x


def batch_at_step(cfg: ArchConfig, dcfg: DataConfig,
                  step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for a global step (restart-safe)."""
    rng = np.random.default_rng(
        (dcfg.seed * 1_000_003 + step) * 97 + dcfg.shard)
    n = dcfg.batch * (dcfg.seq + 1)
    toks = _lcg_tokens(rng, n, cfg.vocab, dcfg.noise)
    toks = toks.reshape(dcfg.batch, dcfg.seq + 1)
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "targets": toks[:, 1:].astype(np.int32)}
    if cfg.family == "audio":
        out["frames"] = rng.standard_normal(
            (dcfg.batch, dcfg.seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        out["image_embeds"] = rng.standard_normal(
            (dcfg.batch, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    return out


def stream(cfg: ArchConfig, dcfg: DataConfig,
           start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at_step(cfg, dcfg, step)
        step += 1
