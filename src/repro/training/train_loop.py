"""Training loop: jit'd train_step factory (with donation + optional int8
gradient compression), periodic checkpointing, and crash-restart resume.

``make_train_step`` is also the entry point lowered by the multi-pod dry-run
for ``train_4k`` shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.checkpoint import CheckpointManager
from repro.models.model import Model
from repro.training import data as data_lib
from repro.training.optimizer import (AdamWConfig, adamw_init,
                                      adamw_update)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    grad_compression: bool = False   # int8 stochastic-rounding compression


def _compress_grads_int8(grads: Any, rng: jax.Array) -> Any:
    """Simulated gradient compression: quantize to int8 per-leaf scale and
    dequantize (models the bandwidth/accuracy trade-off of compressed
    all-reduce; on real multi-host this halves gradient bytes twice over)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        noise = jax.random.uniform(k, g.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(g32 / scale + noise), -127, 127)
        out.append((q * scale).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


def make_train_step(model: Model, tcfg: TrainConfig = TrainConfig()
                    ) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": AdamWState, "rng": key}
    """

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params, opt_state, rng = state["params"], state["opt"], state["rng"]
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        rng, sub = jax.random.split(rng)
        if tcfg.grad_compression:
            grads = _compress_grads_int8(grads, sub)
        params, opt_state, info = adamw_update(tcfg.opt, grads, opt_state,
                                               params)
        new_state = {"params": params, "opt": opt_state, "rng": rng}
        metrics = {"loss": loss, **info}
        return new_state, metrics

    return train_step


def init_train_state(model: Model, rng: jax.Array) -> Dict[str, Any]:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params), "rng": rng}


def train(model: Model, dcfg: data_lib.DataConfig,
          steps: int, tcfg: TrainConfig = TrainConfig(),
          ckpt_dir: Optional[str] = None,
          fail_at_step: Optional[int] = None,
          log: Optional[Callable[[str], None]] = None) -> Dict[str, Any]:
    """Run (or resume) training. ``fail_at_step`` injects a crash for the
    restart tests. Returns {"state", "losses", "resumed_from"}."""
    log = log or (lambda s: None)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    mgr = CheckpointManager(ckpt_dir, keep=tcfg.ckpt_keep) if ckpt_dir \
        else None

    state = init_train_state(model, jax.random.PRNGKey(dcfg.seed))
    start = 0
    resumed_from = None
    if mgr is not None and mgr.latest_step() is not None:
        start, state = mgr.restore(like=state)
        resumed_from = start
        log(f"resumed from checkpoint at step {start}")

    losses = []
    for step in range(start, steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = {k: jnp.asarray(v)
                 for k, v in data_lib.batch_at_step(model.cfg, dcfg,
                                                    step).items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % tcfg.log_every == 0:
            log(f"step {step}: loss={loss:.4f} "
                f"lr={float(metrics['lr']):.2e}")
        if mgr is not None and (step + 1) % tcfg.ckpt_every == 0:
            mgr.save(step + 1, state)
    if mgr is not None:
        mgr.save(steps, state)
    return {"state": state, "losses": losses, "resumed_from": resumed_from}
