from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.training.train_loop import (TrainConfig, init_train_state,  # noqa: F401
                                       make_train_step, train)
