"""AdamW + schedules, implemented directly in JAX (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw_init(params: Any) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    params = jax.tree.unflatten(treedef, new_p)
    state = AdamWState(step=step, mu=jax.tree.unflatten(treedef, new_m),
                       nu=jax.tree.unflatten(treedef, new_v))
    return params, state, {"lr": lr, "grad_norm": gnorm}
