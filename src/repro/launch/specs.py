"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation: everything is built with jax.eval_shape /
ShapeDtypeStruct; the dry-run attaches NamedShardings via jit in_shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models.model import Model


def batch_structs(cfg: ArchConfig, batch: int, seq: int,
                  with_targets: bool = True) -> Dict[str, Any]:
    out = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if with_targets:
        out["targets"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                             jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


def param_structs(model: Model) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def train_state_structs(model: Model) -> Any:
    from repro.training.train_loop import init_train_state
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0)))


def decode_structs(model: Model, shape: ShapeConfig) -> Tuple[Any, Any, Any]:
    """(cache, token, pos) structs for serve_step: one new token against a
    cache of shape.seq_len (the last slot receives the new token)."""
    cache = model.cache_shapes(shape.global_batch, shape.seq_len,
                               enc_len=shape.seq_len)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, pos


def input_specs(model: Model, shape: ShapeConfig) -> Dict[str, Any]:
    """All entry-point inputs for one cell, keyed by argument name."""
    cfg = model.cfg
    if shape.kind == "train":
        return {"state": train_state_structs(model),
                "batch": batch_structs(cfg, shape.global_batch,
                                       shape.seq_len)}
    if shape.kind == "prefill":
        return {"params": param_structs(model),
                "batch": batch_structs(cfg, shape.global_batch,
                                       shape.seq_len, with_targets=False)}
    cache, token, pos = decode_structs(model, shape)
    return {"params": param_structs(model), "cache": cache,
            "token": token, "pos": pos}
