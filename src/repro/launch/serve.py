"""Cluster serving launcher.

Brings up the INFaaS control plane (master + workers + autoscalers),
registers the selected architectures, and drives a Poisson workload.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --workers 2 --rate 50 --duration 60 --slo-ms 100

``--backend`` picks the data plane behind the workers:

* ``sim`` (default) — profile-driven executors; any scale, no JAX
  execution.
* ``real`` — every worker runs an ``EngineExecutor``: jobs execute for
  real on reduced-config continuous-batching engines (host CPU), measured
  service times drive the clock, and variant profiles are re-fit from the
  measurements (reported at the end).

``--real-engine`` instead drives one real continuous-batching engine
directly (no control plane) with a mixed-length stream and reports
measured tokens/sec and compile counts — the standalone data-plane check.

``--clock wall`` (requires ``--backend real``) runs the control plane
against ``RealClock`` as a long-running server: a seeded Poisson client
submits payload-carrying queries live, stepper threads drive the engines,
and tokens stream back per decode segment (TTFT is reported alongside
completion latency). SIGINT drains in-flight work and exits cleanly.
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

from repro.configs.registry import ARCHS
from repro.core.api import QueryPayload, QuerySpec
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals
from benchmarks.common import steady_metrics  # noqa: E402


def _real_engine_demo(arch: str, n_reqs: int, slots: int,
                      page_size: Optional[int] = None,
                      n_pages: Optional[int] = None,
                      chunk_threshold: Optional[int] = None,
                      stage_slots: int = 0,
                      admission: str = "worstcase",
                      preempt_policy: str = "slack",
                      prefix_cache: bool = False,
                      prefix_evict: str = "lru") -> None:
    import time

    import jax
    import numpy as np

    from repro.models import build_model
    from repro.serving.engine import Request, ServingEngine

    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=slots, max_len=64,
                        decode_block=16, page_size=page_size,
                        n_pages=n_pages, chunk_threshold=chunk_threshold,
                        stage_slots=stage_slots, admission=admission,
                        preempt_policy=preempt_policy,
                        prefix_cache=prefix_cache,
                        prefix_evict=prefix_evict)
    rng = np.random.default_rng(0)
    # with the prefix cache on, give the stream something to share: half
    # the requests open with a common template (a system prompt stand-in)
    tpl = (rng.integers(0, cfg.vocab, size=24).astype(np.int32)
           if prefix_cache else None)

    def _prompt(i):
        body = rng.integers(0, cfg.vocab,
                            size=int(rng.integers(4, 29))).astype(np.int32)
        if tpl is not None and i % 2 == 0:
            # stay inside max_len 64 with max_new up to 32
            return np.concatenate([tpl, body])[:32]
        return body

    reqs = [Request(rid=i, prompt=_prompt(i),
                    max_new_tokens=int(rng.integers(4, 33)))
            for i in range(n_reqs)]
    eng.warmup(prompt_lens=[len(r.prompt) for r in reqs])
    t0 = time.perf_counter()
    eng.serve(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in reqs)
    s = eng.stats
    layout = (f"paged {eng.n_pages}x{eng.page_size}"
              if eng._paged else "contiguous")
    print(f"real engine [{cfg.name}] ({layout}): "
          f"{len(reqs)} reqs / {toks} tokens in "
          f"{wall*1e3:.1f} ms = {toks/wall:.0f} tok/s "
          f"({s['prefill_dispatches']}+{s['decode_dispatches']} dispatches, "
          f"{s['prefill_traces']}+{s['decode_traces']} compiles, "
          f"peak {s['peak_concurrency']} slots, "
          f"{s['chunk_admits']} chunked admits, "
          f"{s['inseg_admissions']} in-segment admits, "
          f"{s['preemptions']} preemptions, "
          f"segment occupancy {eng.occupancy['slot_busy_frac']:.2f})")
    if eng._prefix is not None:
        print(f"  prefix cache: {s['prefix_hits']} hits, "
              f"{s['prefix_pages_reused']} pages reused, "
              f"{s['prefix_tokens_skipped']} prefill tokens skipped, "
              f"{s['cow_copies']} COW copies, "
              f"{s['evictions']} evictions")


def _serve_wall(c, arch_names, args) -> None:
    """Long-running wall-clock server: a seeded Poisson client submits
    payload-carrying queries on the RealClock scheduler thread, tokens
    stream back per decode segment, and SIGINT (or the duration horizon)
    drains in-flight work before a clean exit."""
    import signal
    import threading
    import time

    import numpy as np

    from benchmarks.common import pct

    rng = np.random.default_rng(0)
    # payload shape fits the default reduced engine (max_len 32): several
    # decode segments per request so TTFT genuinely precedes completion
    prompt_lens = (4, 13)
    max_new = 8
    vocabs = {a: ARCHS[a].reduced().vocab for a in arch_names}
    handles: list = []
    streamed = {"chunks": 0, "tokens": 0}
    stop = threading.Event()
    loop = c.loop

    def on_sigint(signum, frame):
        print("\nSIGINT: draining in-flight work...", flush=True)
        stop.set()

    prev = signal.signal(signal.SIGINT, on_sigint)

    def count(chunk):
        streamed["chunks"] += 1
        streamed["tokens"] += len(chunk.tokens)

    def fire():
        # runs on the scheduler thread — the master's dispatch is just
        # another clock callback, so no cross-thread marshaling needed
        if stop.is_set():
            return
        a = arch_names[int(rng.integers(len(arch_names)))]
        prompt = rng.integers(
            0, vocabs[a],
            size=int(rng.integers(*prompt_lens))).astype(np.int32)
        h = c.api.submit(QuerySpec.arch(
            a, latency_ms=args.slo_ms,
            payload=QueryPayload.of([prompt], max_new_tokens=max_new)))
        h.on_tokens(count)
        handles.append(h)

    # seeded Poisson arrivals over [0, duration), scheduled up front on
    # the wall clock (the scheduler thread fires them as time passes)
    t0 = loop.now()
    t = float(rng.exponential(1.0 / max(args.rate, 1e-9)))
    n_arrivals = 0
    while t < args.duration:
        loop.schedule_at(t0 + t, fire)
        n_arrivals += 1
        t += float(rng.exponential(1.0 / max(args.rate, 1e-9)))

    while not stop.is_set():
        if loop.now() - t0 >= args.duration and \
                all(h.done for h in list(handles)):
            break
        time.sleep(0.05)

    # drain: queries already in the system stream out; SIGINT only stops
    # new arrivals (fire checks the flag)
    deadline = time.monotonic() + 30.0
    while not all(h.done for h in list(handles)):
        if time.monotonic() >= deadline:
            print("drain timeout: abandoning remaining work", flush=True)
            break
        time.sleep(0.05)
    for ex in getattr(c, "executors", []):
        ex.shutdown()
    loop.shutdown()
    signal.signal(signal.SIGINT, prev)

    done = [h for h in handles if h.done]
    results = [h.result(timeout=0.001) for h in done]
    ok = [r for r in results if r.ok]
    ttfts = [h.ttft for h in done if h.ttft is not None]
    lats = [r.latency for r in ok]
    wall = loop.now() - t0
    print(f"wall-clock serve [{'/'.join(arch_names)}]: "
          f"{n_arrivals} arrivals, {len(handles)} submitted, "
          f"{len(ok)} completed ok, "
          f"{sum(1 for r in results if r.failed)} failed "
          f"in {wall:.1f}s wall ({len(ok)/max(wall, 1e-9):.2f} q/s)")
    print(f"streamed: {streamed['tokens']} tokens in "
          f"{streamed['chunks']} chunks across {len(done)} queries")
    if ttfts and lats:
        print(f"TTFT p50={pct(ttfts, 50)*1e3:.0f}ms "
              f"p99={pct(ttfts, 99)*1e3:.0f}ms | completion "
              f"p50={pct(lats, 50)*1e3:.0f}ms "
              f"p99={pct(lats, 99)*1e3:.0f}ms")
    print("clean shutdown: drained in-flight work", flush=True)


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="architecture id, or 'all'")
    ap.add_argument("--backend", choices=["sim", "real"], default="sim",
                    help="worker data plane: profiled t(b) models (sim) or "
                         "real reduced-config engines (real)")
    ap.add_argument("--clock", choices=["virtual", "wall"],
                    default="virtual",
                    help="virtual: discrete-event simulation of time; "
                         "wall: long-running server on RealClock with "
                         "threaded engine stepping and token streaming "
                         "(needs --backend real)")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--cpu-workers", type=int, default=1)
    ap.add_argument("--rate", type=float, default=50.0, help="queries/s")
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--slo-ms", type=float, default=100.0)
    ap.add_argument("--no-autoscale", action="store_true")
    ap.add_argument("--hedge", action="store_true",
                    help="enable hedged-request straggler mitigation")
    ap.add_argument("--real-engine", action="store_true",
                    help="drive one real continuous-batching engine "
                         "directly, without the control plane")
    ap.add_argument("--real-reqs", type=int, default=32)
    ap.add_argument("--real-slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged KV cache page size in positions "
                         "(default: contiguous max-shape slots)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV page pool size (default: max_batch * "
                         "max_len / page_size, capacity parity)")
    ap.add_argument("--chunk-threshold", type=int, default=None,
                    help="chunk prompts longer than this through the "
                         "decode loop instead of one prefill dispatch")
    ap.add_argument("--stage-slots", type=int, default=0,
                    help="in-segment admission: device staging ring "
                         "capacity (0 = boundary-only admission)")
    ap.add_argument("--admission", choices=["worstcase", "optimistic"],
                    default="worstcase",
                    help="paged admission control: reserve worst-case "
                         "pages up front, or admit on expected usage and "
                         "preempt under pressure (needs --page-size)")
    ap.add_argument("--preempt-policy", choices=["slack", "lru"],
                    default="slack",
                    help="optimistic-admission victim choice: most SLO "
                         "slack, or most-recently-admitted (lru)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share common prompt prefixes at page "
                         "granularity across requests (copy-on-write; "
                         "needs --page-size)")
    ap.add_argument("--prefix-evict", choices=["lru", "fifo"],
                    default="lru",
                    help="which unreferenced cached page the pool "
                         "reclaims first when it runs dry")
    args = ap.parse_args(argv)

    if args.n_pages is not None and args.page_size is None:
        raise SystemExit("--n-pages sizes the paged KV pool; it needs "
                         "--page-size (contiguous engines have no pool)")
    if args.admission == "optimistic" and args.page_size is None:
        raise SystemExit("--admission optimistic over-commits the paged "
                         "KV pool; it needs --page-size (contiguous "
                         "engines reserve whole slots and cannot "
                         "over-commit)")
    if args.prefix_cache and args.page_size is None:
        raise SystemExit("--prefix-cache shares prompt prefixes at page "
                         "granularity; it needs --page-size (contiguous "
                         "slot rows have no pages to share)")
    if args.clock == "wall" and (args.backend != "real"
                                 or args.real_engine):
        raise SystemExit("--clock wall runs the control plane in real "
                         "time against live engines; it needs --backend "
                         "real (the sim executor resolves service times "
                         "instantly and has nothing to do on a wall "
                         "clock, and --real-engine bypasses the control "
                         "plane entirely)")
    if args.real_engine:
        _real_engine_demo(args.arch, args.real_reqs, args.real_slots,
                          page_size=args.page_size, n_pages=args.n_pages,
                          chunk_threshold=args.chunk_threshold,
                          stage_slots=args.stage_slots,
                          admission=args.admission,
                          preempt_policy=args.preempt_policy,
                          prefix_cache=args.prefix_cache,
                          prefix_evict=args.prefix_evict)
        return

    if args.backend != "real" and (args.page_size is not None
                                   or args.n_pages is not None
                                   or args.chunk_threshold is not None
                                   or args.stage_slots
                                   or args.admission != "worstcase"
                                   or args.prefix_cache):
        raise SystemExit(
            "--page-size/--n-pages/--chunk-threshold/--stage-slots/"
            "--admission/--prefix-cache configure the real data plane; "
            "combine them with --backend real or --real-engine (the sim "
            "backend has no KV cache to page and no decode loop to "
            "refill)")
    if args.backend == "real" and args.arch == "all":
        raise SystemExit("--backend real needs a single --arch "
                         "(each arch builds real model params)")
    archs = None if args.arch == "all" else [ARCHS[args.arch]]
    from repro.core.master import MasterConfig
    cfg = MasterConfig(hedge_enabled=args.hedge)
    engine_cfg = None
    if args.backend == "real" and (args.page_size is not None
                                   or args.n_pages is not None
                                   or args.chunk_threshold is not None
                                   or args.stage_slots
                                   or args.admission != "worstcase"
                                   or args.prefix_cache):
        from repro.serving.executor import EngineExecutorConfig
        engine_cfg = EngineExecutorConfig(
            page_size=args.page_size, n_pages=args.n_pages,
            chunk_threshold=args.chunk_threshold,
            stage_slots=args.stage_slots,
            admission=args.admission,
            preempt_policy=args.preempt_policy,
            prefix_cache=args.prefix_cache,
            prefix_evict=args.prefix_evict)
    c = make_cluster(n_accel=args.workers, n_cpu=args.cpu_workers,
                     archs=archs, autoscale=not args.no_autoscale, cfg=cfg,
                     backend=args.backend, engine_cfg=engine_cfg,
                     clock=args.clock)
    arch_names = [a for a in (
        [args.arch] if args.arch != "all" else list(ARCHS))]

    if args.clock == "wall":
        _serve_wall(c, arch_names, args)
        return

    import numpy as np
    rng = np.random.default_rng(0)

    def fire(t):
        a = arch_names[rng.integers(len(arch_names))]
        c.api.submit(QuerySpec.arch(a, latency_ms=args.slo_ms))

    poisson_arrivals(c.loop, lambda t: args.rate, fire,
                     t_end=args.duration, seed=0)
    c.run_until(args.duration + 30.0)
    m = steady_metrics(c.master.metrics, 0.0, args.duration + 30.0,
                       warmup=min(20.0, args.duration / 3.0))
    print(f"served={m['completed']} thr={m['throughput_qps']:.1f} q/s "
          f"viol={m['violation_rate']:.3f} p50={m['p50_ms']:.1f}ms "
          f"p99={m['p99_ms']:.1f}ms")
    alive = sum(1 for w in c.store.workers.values() if w.alive)
    print(f"workers alive at end: {alive}")
    if args.backend == "real":
        measured = [v for v in c.store.registry.variants.values()
                    if v.profile.source == "measured"]
        for v in measured:
            print(f"measured profile {v.name}: "
                  f"t(b) = {v.profile.m*1e3:.2f}ms*b + "
                  f"{v.profile.c*1e3:.2f}ms")
        print(f"variants re-fit from real measurements: {len(measured)}")


if __name__ == "__main__":
    main()
