"""Cluster serving launcher.

Brings up the INFaaS control plane (master + workers + autoscalers) against
either the simulated executors (default; any scale) or the real host
executor (reduced configs), registers the selected architectures, and
drives a workload.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --workers 2 --rate 50 --duration 60 --slo-ms 100
"""
from __future__ import annotations

import argparse

from repro.configs.registry import ARCHS
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals
from benchmarks.common import steady_metrics  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="architecture id, or 'all'")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--cpu-workers", type=int, default=1)
    ap.add_argument("--rate", type=float, default=50.0, help="queries/s")
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--slo-ms", type=float, default=100.0)
    ap.add_argument("--no-autoscale", action="store_true")
    ap.add_argument("--hedge", action="store_true",
                    help="enable hedged-request straggler mitigation")
    args = ap.parse_args()

    archs = None if args.arch == "all" else [ARCHS[args.arch]]
    from repro.core.master import MasterConfig
    cfg = MasterConfig(hedge_enabled=args.hedge)
    c = make_cluster(n_accel=args.workers, n_cpu=args.cpu_workers,
                     archs=archs, autoscale=not args.no_autoscale, cfg=cfg)
    arch_names = [a for a in (
        [args.arch] if args.arch != "all" else list(ARCHS))]

    import numpy as np
    rng = np.random.default_rng(0)

    def fire(t):
        a = arch_names[rng.integers(len(arch_names))]
        c.api.online_query(mod_arch=a, latency_ms=args.slo_ms)

    poisson_arrivals(c.loop, lambda t: args.rate, fire,
                     t_end=args.duration, seed=0)
    c.run_until(args.duration + 30.0)
    m = steady_metrics(c.master.metrics, 0.0, args.duration)
    print(f"served={m['completed']} thr={m['throughput_qps']:.1f} q/s "
          f"viol={m['violation_rate']:.3f} p50={m['p50_ms']:.1f}ms "
          f"p99={m['p99_ms']:.1f}ms")
    alive = sum(1 for w in c.store.workers.values() if w.alive)
    print(f"workers alive at end: {alive}")


if __name__ == "__main__":
    main()
