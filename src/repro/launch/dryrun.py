import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first use.

"""Multi-pod dry-run.

Lowers + compiles every (architecture x input-shape x mesh) cell against the
production meshes (16x16 single-pod, 2x16x16 multi-pod), records
memory_analysis / cost_analysis / collective bytes, and writes a JSON
manifest consumed by EXPERIMENTS.md and benchmarks/roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS
from repro.configs.shapes import SHAPES, shape_applicable
from repro.distributed.parallel import (ParallelConfig,
                                        activation_sharding_from,
                                        set_activation_sharding)
from repro.distributed import sharding as shd
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (analytic_compute_s, model_flops,
                                   parse_collective_bytes, roofline_terms)
from repro.models.model import build_model
from repro.training.train_loop import make_train_step


def _logits_spec(cfg, batch, ax):
    return P(shd._dax(ax, batch), None, shd._max(ax, cfg.vocab))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               attention_impl: str = "xla_chunked"):
    """Build (jitted_fn, kwargs-of-ShapeDtypeStructs) for one cell."""
    # flash-style chunked attention is the lowering default: the S x T score
    # matrix must never materialize at 32k-524k (Pallas kernel on real TPU).
    cfg = dataclasses.replace(ARCHS[arch], attention_impl=attention_impl)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = shd.MeshAxes.from_mesh(mesh)
    parallel = ParallelConfig(mesh=mesh, data_axes=ax.data,
                              model_axis=ax.model, moe_impl="ep")
    set_activation_sharding(activation_sharding_from(parallel))
    model = build_model(cfg, parallel)
    ins = specs_lib.input_specs(model, shape)
    def named(specs):
        return shd.to_named(mesh, specs)

    if shape.kind == "train":
        step = make_train_step(model)
        state_specs = shd.train_state_specs(cfg, ax)
        bspecs = shd.batch_specs(cfg, shape.global_batch, ax)
        metrics_specs = {"loss": P(), "lr": P(), "grad_norm": P()}
        fn = jax.jit(step,
                     in_shardings=(named(state_specs), named(bspecs)),
                     out_shardings=(named(state_specs),
                                    named(metrics_specs)))
        args = (ins["state"], ins["batch"])
    elif shape.kind == "prefill":
        pspecs = shd.param_specs(cfg, ax)
        bspecs = shd.batch_specs(cfg, shape.global_batch, ax,
                                 with_targets=False)
        cspecs = shd.cache_specs(cfg, shape.global_batch, ax)
        fn = jax.jit(model.prefill,
                     in_shardings=(named(pspecs), named(bspecs)),
                     out_shardings=(
                         named(_logits_spec(cfg, shape.global_batch, ax)),
                         named(cspecs)))
        args = (ins["params"], ins["batch"])
    else:
        pspecs = shd.param_specs(cfg, ax)
        cspecs = shd.cache_specs(cfg, shape.global_batch, ax)
        tok_spec = P(shd._dax(ax, shape.global_batch), None)
        # NOTE §Perf A-iter1: donating the cache (donate_argnums=(1,)) was
        # tried and REFUTED on this backend: bytes accessed rose 24% (extra
        # layout conversions outweigh the saved copy in the lowering).
        fn = jax.jit(model.decode,
                     in_shardings=(named(pspecs), named(cspecs),
                                   named(tok_spec), named(P())),
                     out_shardings=(
                         named(_logits_spec(cfg, shape.global_batch, ax)),
                         named(cspecs)))
        args = (ins["params"], ins["cache"], ins["token"], ins["pos"])
    return mesh, fn, args, shape, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = ARCHS[arch]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name,
                           "entry_point": shape.entry_point}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    try:
        mesh, fn, args, shape, cfg = lower_cell(arch, shape_name, multi_pod)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
        n_dev = mesh.size
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        coll_bytes = float(sum(coll.values()))
        terms = roofline_terms(cost, coll_bytes, n_dev)
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            devices=n_dev,
            # memory_analysis proves the per-device footprint fits
            bytes_per_device={
                "arguments": int(getattr(mem, "argument_size_in_bytes", 0)),
                "outputs": int(getattr(mem, "output_size_in_bytes", 0)),
                "temps": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak": int(getattr(mem, "temp_size_in_bytes", 0)
                            + getattr(mem, "argument_size_in_bytes", 0)),
            },
            cost_per_device={k: float(v) for k, v in cost.items()
                             if k in ("flops", "bytes accessed",
                                      "transcendentals")},
            collective_bytes_per_device=coll,
            roofline={
                "compute_s": terms.compute_s,
                "compute_s_analytic": analytic_compute_s(cfg, shape, n_dev),
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
                "step_time_s": terms.step_time_s,
                "model_flops": mf,
                "hlo_flops_global": terms.flops_global,
                "useful_flops_ratio": mf / terms.flops_global
                if terms.flops_global else 0.0,
            },
        )
        if keep_hlo:
            rec["hlo_path"] = f"/tmp/hlo_{arch}_{shape_name}_{mesh_name}.txt"
            with open(rec["hlo_path"], "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — a cell failure is data
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    finally:
        set_activation_sharding(None)
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (or all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append records to JSONL")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present (ok/skipped) in --out")
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    done = set()
    if args.resume and args.out:
        import os
        if os.path.exists(args.out):
            with open(args.out) as f:
                for line in f:
                    r = json.loads(line)
                    if r["status"] in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape, mesh_name) in done:
                    continue
                rec = run_cell(arch, shape, mp, keep_hlo=args.keep_hlo)
                records.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" step={r['step_time_s']:.4f}s"
                             f" peak_mem={rec['bytes_per_device']['peak']/2**30:.2f}GiB")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{status:7s}] {arch:24s} {shape:12s} "
                      f"{rec['mesh']:8s}{extra}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\n{n_ok} ok, {n_skip} skipped (documented), {n_err} errors "
          f"of {len(records)} cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
