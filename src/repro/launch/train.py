"""Training launcher: real execution on host for reduced configs, or
``--dryrun`` to lower/compile the full config on the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 100 --ckpt /tmp/run1
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-235b-a22b \
        --dryrun --multi-pod
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the FULL config on the production "
                         "mesh instead of training the reduced config")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dryrun:
        # must set the device-count flag before importing anything jax-y
        from repro.launch import dryrun as dr
        rec = dr.run_cell(args.arch, "train_4k", args.multi_pod)
        import json
        print(json.dumps(rec, indent=2))
        return

    from repro.configs.registry import ARCHS
    from repro.models import build_model
    from repro.training import data as data_lib
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import TrainConfig, train

    cfg = ARCHS[args.arch].reduced()
    model = build_model(cfg)
    dcfg = data_lib.DataConfig(batch=args.batch, seq=args.seq)
    tcfg = TrainConfig(opt=AdamWConfig(total_steps=args.steps))
    out = train(model, dcfg, steps=args.steps, tcfg=tcfg,
                ckpt_dir=args.ckpt, log=print)
    print(f"final loss {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
