"""Roofline-term derivation from compiled dry-run artifacts (TPU v5e target).

    compute term    = HLO_FLOPs   / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes   / (chips x 819 GB/s)
    collective term = coll_bytes  / (chips x 50 GB/s per ICI link)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) module, so
its flops/bytes are multiplied by the device count to obtain the global
numerators above. Collective bytes are not in cost_analysis: we parse the
optimized HLO and sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.sim.hardware import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes received by each collective family.

    The optimized HLO does not annotate operand types inline, so we sum the
    RESULT shapes of each collective instruction: exact for all-reduce /
    collective-permute, equals bytes received for all-gather / all-to-all,
    and understates reduce-scatter by the group size (documented caveat;
    reduce-scatter + all-gather pairs dominate where it matters and the
    all-gather side is counted fully).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped.startswith("%"):
            continue
        for coll in _COLLECTIVES:
            if f" {coll}(" in stripped and f"%{coll}" in stripped.split(
                    "=", 1)[0] + " " + stripped:
                # result shapes sit left of the op name; metadata right of it
                head = stripped.split(f" {coll}(", 1)[0]
                for m in _SHAPE_RE.finditer(head):
                    out[coll] += _shape_bytes(m.group(1), m.group(2))
                break
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_global: float
    bytes_global: float
    coll_bytes_per_dev: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(cost_per_dev: Dict[str, float],
                   coll_bytes_per_dev: float, chips: int) -> RooflineTerms:
    flops_g = cost_per_dev.get("flops", 0.0) * chips
    bytes_g = (cost_per_dev.get("bytes accessed", 0.0)) * chips
    compute = flops_g / (chips * V5E_PEAK_FLOPS_BF16)
    memory = bytes_g / (chips * V5E_HBM_BW)
    collective = coll_bytes_per_dev / V5E_ICI_BW
    return RooflineTerms(compute_s=compute, memory_s=memory,
                         collective_s=collective, flops_global=flops_g,
                         bytes_global=bytes_g,
                         coll_bytes_per_dev=coll_bytes_per_dev)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D dense (training) / 2*N*D inference; MoE uses
    active params."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def analytic_compute_s(cfg, shape, chips: int) -> float:
    """Cross-check compute term from MODEL_FLOPS (x4/3 remat recompute for
    training). XLA's cost_analysis undercounts FLOPs inside nested scan
    loops (it reports the per-device partitioned module with loop bodies
    counted a bounded number of times), so this analytic term is reported
    alongside the HLO-derived one in §Roofline."""
    remat = 4.0 / 3.0 if shape.kind == "train" else 1.0
    return model_flops(cfg, shape) * remat / (chips * V5E_PEAK_FLOPS_BF16)
