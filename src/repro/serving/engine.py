"""Real-execution serving data plane: continuous-batching, device-resident
decode engine with shape bucketing.

This is the data plane behind a ``JaxExecutor`` worker: the INFaaS control
plane picks the variant; this engine actually runs it. The design replaces
the seed's run-to-completion waves (one device dispatch *and one host sync
per generated token*, one XLA compile per distinct ``(batch, prompt_len)``)
with three mechanisms:

**Slot scheduler (continuous batching).** The engine owns a preallocated
max-shape KV cache of ``max_batch`` slots x ``max_len`` positions plus
per-slot ``tok``/``pos``/``remaining`` arrays, all device-resident. A
request is admitted by prefilling its prompt (batch 1, right-padded to a
bucket) and inserting the resulting cache into a free slot via
``dynamic_update_slice`` along each leaf's batch axis — there is no
post-prefill ``_pad_cache`` copy of the whole batch. Slots are freed the
moment their sequence finishes and refilled from the pending queue between
decode segments, so short requests never wait for the longest request in a
wave.

**Fused decode segments.** Decoding runs as a ``lax.while_loop`` over
``model.decode`` inside one jitted function: up to ``decode_block`` tokens
for all slots are generated in a single device dispatch with a single
host sync at the end (the seed engine synced every token). Each slot
carries its own position vector (``decode``'s per-sequence ``pos``) and an
activity mask; finished slots stop advancing, and the loop exits early
when every slot is done, so drained batches stop costing FLOPs.

**Shape bucketing + warmup.** Prompt lengths are padded up to power-of-two
buckets (>= ``min_bucket``, <= ``max_len``) and admit batches are bucketed
to {1, max_batch} (same-bucket prompts admitted in one dispatch; padding
rows scatter out of bounds and are dropped), with prefill executables
keyed on the (bucket_batch, bucket_len) pair — a mixed-length request
stream compiles at most two prefills per prompt bucket and exactly one
decode-segment program per engine.
``warmup(prompt_lens=...)`` triggers those compiles eagerly so calibration
(``JaxExecutor``) and latency-sensitive serving never pay compile time
inside a measured service time. ``stats`` counts actual retraces
(``prefill_traces`` / ``decode_traces``), which tests pin down.

**Paged KV cache (block tables).** With ``page_size=None`` (default) every
slot owns a contiguous ``max_len`` run of KV positions, so slot count is
bound by worst-case context length even when most requests are short —
exactly the over-provisioning INFaaS's model-level autoscaling argues
against. With ``page_size=P`` the attention cache becomes a shared page
pool ``(L, n_pages, P, K, D)`` plus a per-slot block table
(``repro.models.kvcache``): admission is gated on *free pages* (a request
reserves ``ceil((prompt + max_new - 1) / P)`` pages, its worst case) rather
than free max-shape slots, pages are appended to a slot's block table as
its ``pos`` crosses a page boundary (topped up ahead of each decode
segment) and returned to the free list the moment the sequence finishes.
``n_pages`` defaults to ``max_batch * max_len / page_size`` (capacity
parity); provisioning fewer pages than slots-worth is the point — a
long-tail stream of mostly-short requests runs ``n_pages * P / max_len``-
slot hardware at far higher concurrency. Recurrent families' O(1) states
(SSM/conv/xLSTM) have no sequence axis and stay slot-indexed; greedy
outputs are bit-identical to the contiguous engine (the gathered view an
attention step sees is position-for-position the same tensor).

**Chunked prefill.** A long prompt's monolithic prefill dispatch used to
stall every in-flight decode for the whole prompt length. With
``chunk_threshold=T`` set, prompts longer than ``T`` skip the prefill
dispatch entirely: the prompt is staged in a device-resident per-slot
prompt buffer and *teacher-forced through the fused decode segment* —
each segment consumes up to ``decode_block`` prompt tokens for that slot
(writing KV, discarding logits until the prompt is exhausted, then
switching to greedy emission) while other slots keep generating in the
same dispatch. A near-``max_len`` prompt admitted mid-stream therefore
delays in-flight decodes by zero extra dispatches. Chunked admission is
enabled for the dense/hybrid/ssm families — each slot restarts from the
family's empty decode state via ``Model.empty_state`` (all-zeros, except
xLSTM's -inf stabilizers). Audio/vlm need encoder KV from prefill, and
MoE's expert-capacity keep/drop decisions depend on the co-batched token
set (prompt tokens fed inside the shared decode batch would diverge from
the solo prefill the engine guarantees), so those families admit whole
prompts regardless of the knob.

**In-segment admission (staging ring).** Even with chunked prefill, a slot
that finishes mid-segment idles until the ``lax.while_loop`` exits, and a
newly arrived request waits for the next ``step()`` boundary — the
occupancy bubble that inflates tail latency under bursty short-request
load. With ``stage_slots=N`` the engine keeps a device-resident staging
ring of up to ``N`` pending requests (prompt rows, lengths, ``max_new``,
and — in paged mode — pre-reserved block-table rows): the decode loop's
carry tracks a ring head, and the moment a slot's ``rem`` hits zero
mid-segment the loop records the completion in a per-slot completion log
and pulls the next staged request into the freed slot — resetting
``pos``/``rem``/``plen``/prompt-buffer pointers, restoring the slot's O(1)
recurrent-state rows to the family's empty state
(``Model.empty_state`` — xLSTM's stabilizers start at -inf, not zero),
and switching the slot to the staged request's block-table row. One
dispatch can therefore retire *multiple* requests per slot with zero
extra dispatches or host syncs; the host decodes the completion log after
the segment to split each slot's emission row between its successive
occupants. Staged requests teacher-force their prompts through the fused
segment exactly like chunked prefill, so in-segment admission is gated to
the same families whose teacher-forced decode is exact from the empty
state (dense/hybrid/ssm); other families clamp ``stage_slots`` to 0 and
keep boundary-only admission. In paged mode a staged request holds its
worst-case page reservation from staging time (its first
``decode_block`` positions' pages are materialized up front, since no
host boundary can top it up mid-segment); ``PageAllocator`` tracks these
staged reservations under per-request tickets that are re-keyed to the
slot at harvest.

**Optimistic admission + SLO-aware preemption.** Worst-case admission
(``admission="worstcase"``, the default) reserves every request's full
``ceil((prompt + max_new - 1) / P)`` pages up front, so the pool is
chronically under-committed: the decode tail is reserved long before it is
written, and the only failure mode under pressure is head-of-line
queueing. ``admission="optimistic"`` admits on *expected* usage instead —
a prefill request needs its prompt pages now (they are scattered at the
prefill dispatch) and a chunked request only its first ``decode_block``
stride — and grows the decode tail lazily. When the pool runs dry at a
growth point (a live slot's ``pos`` is about to cross a page boundary
with zero free pages — at the segment-boundary top-up, or because staged
in-segment refills hold pages), the engine *preempts* instead of wedging:
staged-but-unstarted requests are un-staged first (zero work lost), then
a live victim is chosen, its pages freed, and the request parked host-side
with its prompt plus every token generated so far. Re-admission
teacher-forces that full prefix through the chunked-prefill path, so
recovery is **bit-identical** to an uninterrupted run (greedy decode is
deterministic given the prefix). Victim choice is SLO-aware
(``preempt_policy="slack"``): each ``Request`` carries its latency
objective (``slo``), and the engine preempts the request with the most
slack — deadline minus elapsed minus estimated remaining (segment-time
EWMA x positions left) — treating no-SLO requests as infinite slack and
breaking ties toward longest-remaining; ``preempt_policy="lru"`` preempts
the most recently admitted request instead (vLLM-style recompute).
Optimistic admission requires the paged layout and a family whose
teacher-forced decode is exact from the empty state (dense/hybrid/ssm);
other configurations clamp back to worst-case. ``stats`` counts
``preemptions``, ``preempt_readmits`` and ``pressure_stalls`` (growth
points that found the pool dry), and each ``Request`` counts its own
``preemptions`` so callers can surface a ``degraded`` flag.

**Occupancy accounting.** ``stats`` tracks ``busy_slot_steps`` /
``bubble_slot_steps`` (active vs idle slot-steps inside fused segments,
counted in the loop carry), ``inseg_admissions`` and ``staged``; the
``occupancy`` property derives the per-segment slot-busy fraction and
admissions-per-segment that ``EngineExecutor`` threads into its
decision log.

**Open-loop core.** The engine is step-driven: state (slot occupancy,
pending queue, per-slot generations) persists on the engine, and the three
phases of the serving loop are separately callable —

* ``submit(req)``     enqueue a request (at any time, including while other
  requests are mid-decode); its latency clock starts at ``Request.arrival``
  (stamped at submit if unset),
* ``step()``          admit pending requests into free slots, run ONE fused
  decode segment, harvest finished slots,
* ``drain_completions()``  collect requests finished since the last drain.

Mid-stream admission falls out: a request submitted between segments joins
the next ``step()`` without restarting in-flight slots. ``serve()`` is a
thin closed loop over the core (submit all, step until idle) and produces
bit-identical outputs and identical trace/dispatch counts to the closed
PR-1 loop. The open seam is what lets the INFaaS control plane
(``EngineExecutor`` in ``repro.serving.executor``) drive real engines.

Exactness: for the dense/hybrid/ssm (and, by the same causal-masking
argument, vlm) families the engine emits token-for-token the same greedy
outputs as a serial per-request prefill+decode (prompts are right-padded;
causal attention masks padded KV via per-sequence valid lengths, and
recurrent families mask their state updates — see ``repro.models.model``).
MoE matches serial decode except when GShard-style expert capacity —
a static function of the padded token count — crosses a boundary between
the prompt's bucket and its exact length and flips a token-drop decision
(see ``prefill_moe``); MoE prompts are therefore admitted one per
dispatch, which keeps decode exact and confines the effect to prefill.
The audio family masks its encoder self-attention and decoder
cross-attention by each request's true encoder length (threaded through
the cache as a per-slot ``enc_len``), so padded encoder rows contribute
exact zeros: audio outputs are padding-independent, and the paged layout
(whose dropped writes leave padding rows stale) is bit-identical to
contiguous for audio too.

The seed wave engine survives as ``WaveEngine`` — the benchmark baseline
for ``benchmarks/fig_engine_throughput.py``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import kvcache as KV
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 8
    arrival: float = 0.0
    tokens: Optional[np.ndarray] = None
    latency: float = 0.0
    # wall time the request entered a device slot (prefill, chunked, or
    # in-segment promotion at harvest); admitted - arrival is queue delay
    admitted: float = -1.0
    # per-query latency objective in seconds (deadline = arrival + slo);
    # None = best-effort. Drives SLO-aware victim choice under pressure.
    slo: Optional[float] = None
    # times this request was preempted (pages freed, parked, prefix
    # replayed); > 0 lets callers surface a "degraded" flag on results
    preemptions: int = 0
    # streaming cursor: tokens [0, streamed) were already handed out via
    # ``drain_partial_outputs`` — survives preempt/replay, so a re-admitted
    # request never re-streams tokens it delivered before parking
    streamed: int = 0
    # wall time the first generated token was harvested (segment
    # granularity); -1 until it happens. first_token - arrival is TTFT.
    first_token: float = -1.0


@dataclasses.dataclass
class _Parked:
    """A preempted request parked host-side awaiting re-admission."""
    req: Request
    prefix: np.ndarray      # prompt + every token generated before preempt
    done: List[int]         # tokens already generated (re-credited at seat)


def bucket_len(n: int, minimum: int = 8, maximum: Optional[int] = None) -> int:
    """Round ``n`` up to a power of two >= ``minimum`` (clamped to maximum)."""
    b = max(minimum, 1 << max(int(n) - 1, 0).bit_length())
    if maximum is not None:
        if n > maximum:
            raise ValueError(f"length {n} exceeds engine max_len {maximum}")
        b = min(b, maximum)
    return b


class PageAllocator:
    """Host-side accounting for the shared KV page pool.

    Admission reserves a holder's worst case (``ceil(n_positions /
    page_size)`` pages for ``prompt_len + max_new - 1`` written positions)
    so a decode can never strand mid-stream for lack of pages — ``cover()``
    calls, which lazily hand out physical pages as ``pos`` grows, always
    succeed within the reservation. Holders are arbitrary hashable keys:
    the engine keys live slots by slot index and staged-but-unadmitted
    requests (in-segment admission) by per-request tickets, re-keyed to
    the slot via ``rekey()`` when the staging ring promotes them.
    Invariants (pinned by the hypothesis property test): no page is ever
    held by two live holders, ``free + staged + live == n_pages`` at all
    times, and a full drain returns every page to the free list.

    **Prefix sharing** relaxes "no page held by two holders" into
    refcounting: ``attach()`` points an additional holder at pages some
    other holder (or the prefix cache) already owns, ``release()``
    decrements and only a count of zero returns the page — either to the
    free list or, when the ``retain`` hook claims it (the prefix cache
    retains pages it has indexed), to a *cached* pool of reclaimable
    rc==0 pages. ``cover()`` and ``cow()`` fall back to evicting a cached
    page (``evict_choice`` picks, ``on_evict`` notifies the index) when
    the free list runs dry, so caching never reduces usable capacity.
    The sharing-era invariants, pinned by the extended property test:
    ``free + cached + unique_live == n_pages``, a page's refcount equals
    the number of holders listing it, and eviction only ever takes rc==0
    pages.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError(f"bad pool: {n_pages} pages x {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages))[::-1]
        self._pages: Dict[Any, List[int]] = {}     # holder -> held page ids
        self._reserved: Dict[Any, int] = {}        # holder -> worst case
        self._refcnt: Dict[int, int] = {}          # page -> live holders
        self._cached: Dict[int, None] = {}         # rc==0 retained pages
        # prefix-cache seams (all optional): ``retain(page) -> bool``
        # claims an rc==0 page for the cached pool instead of the free
        # list; ``evict_choice() -> page`` picks which cached page to
        # reclaim under free-list pressure; ``on_evict(page)`` tells the
        # index the page's contents are about to be overwritten.
        self.retain = None
        self.evict_choice = None
        self.on_evict = None
        self.evictions = 0

    def pages_needed(self, n_positions: int) -> int:
        return max(0, -(-int(n_positions) // self.page_size))

    @property
    def committed(self) -> int:
        """Pages promised to live slots (held now or claimable later)."""
        return sum(self._reserved.values())

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        """rc==0 pages retained by the prefix cache (reclaimable)."""
        return len(self._cached)

    @property
    def n_avail(self) -> int:
        """Pages a cover/cow can actually obtain: free + evictable."""
        return len(self._free) + len(self._cached)

    def refcount(self, page: int) -> int:
        return self._refcnt.get(page, 0)

    def live_pages(self) -> List[int]:
        return [p for pages in self._pages.values() for p in pages]

    def pages_of(self, slot: int) -> List[int]:
        return list(self._pages.get(slot, ()))

    def can_reserve(self, n_positions: int) -> bool:
        return self.committed + self.pages_needed(n_positions) <= self.n_pages

    def reserve(self, slot: Any, n_positions: int,
                strict: bool = True) -> None:
        """Admit ``slot``: commit its worst-case page count (no pages yet).

        ``strict=False`` (optimistic admission) skips the over-commit
        check: the engine admits on expected usage, lets ``committed``
        exceed the pool, and resolves a dry pool by preemption instead of
        up-front refusal."""
        if slot in self._reserved:
            raise ValueError(f"slot {slot} already live")
        need = self.pages_needed(n_positions)
        if strict and self.committed + need > self.n_pages:
            raise ValueError(f"over-committed: {self.committed}+{need} "
                             f"> {self.n_pages}")
        self._reserved[slot] = need
        self._pages[slot] = []

    def can_cover(self, holder: Any, n_positions: int) -> bool:
        """Enough obtainable pages for ``cover(holder, n_positions)``?
        Always true under worst-case admission (the reservation pre-funds
        every cover); optimistic admission uses this as its pressure
        probe. Cached rc==0 pages count — they evict on demand."""
        held = len(self._pages[holder])
        target = min(self.pages_needed(n_positions),
                     self._reserved[holder])
        return target - held <= self.n_avail

    def _grab(self) -> int:
        """One physical page at rc==1: the free list first, then evict a
        cached page (rc==0 by construction, so eviction never frees a
        page any live holder references)."""
        if not self._free:
            page = (self.evict_choice() if self.evict_choice
                    else next(iter(self._cached)))
            del self._cached[page]
            if self.on_evict is not None:
                self.on_evict(page)
            self.evictions += 1
            self._refcnt[page] = 1
            return page
        page = self._free.pop()
        self._refcnt[page] = 1
        return page

    def _deref(self, page: int) -> None:
        self._refcnt[page] -= 1
        if self._refcnt[page] == 0:
            del self._refcnt[page]
            if self.retain is not None and self.retain(page):
                self._cached[page] = None
            else:
                self._free.append(page)

    def cover(self, slot: int, n_positions: int) -> List[int]:
        """Grow ``slot`` to cover positions [0, n); returns the new pages."""
        held = self._pages[slot]
        target = min(self.pages_needed(n_positions), self._reserved[slot])
        grown = []
        while len(held) < target:
            page = self._grab()
            grown.append(page)
            held.append(page)
        return grown

    def attach(self, holder: Any, pages: Sequence[int]) -> None:
        """Point ``holder`` at pages already resident elsewhere (a prefix
        cache hit): each page's refcount grows by one, cached rc==0 pages
        come back live, and the pages count toward the holder's
        reservation exactly like pages it covered itself."""
        held = self._pages[holder]
        for p in pages:
            if p in self._cached:
                del self._cached[p]
            self._refcnt[p] = self._refcnt.get(p, 0) + 1
            held.append(p)

    def cow(self, holder: Any, idx: int) -> Tuple[int, int]:
        """Copy-on-write ``holder``'s ``idx``-th page: grab a private
        page at rc==1, swap it into the holder's list, and drop the
        holder's reference to the shared original. Returns ``(shared,
        private)``; the caller copies the page's device contents."""
        held = self._pages[holder]
        old = held[idx]
        new = self._grab()
        held[idx] = new
        self._deref(old)
        return old, new

    def release(self, slot: int) -> List[int]:
        """Drop all of ``slot``'s page references (sequence finished or
        preempted). Pages nobody else references return to the pool —
        free list, or the prefix cache's cached set when indexed."""
        pages = self._pages.pop(slot)
        del self._reserved[slot]
        for p in pages:
            self._deref(p)
        return pages

    def rekey(self, old: Any, new: Any) -> None:
        """Transfer a reservation (and its held pages) to a new holder key:
        a staged request's ticket becomes the slot it was pulled into."""
        if new in self._reserved:
            raise ValueError(f"holder {new!r} already live")
        self._reserved[new] = self._reserved.pop(old)
        self._pages[new] = self._pages.pop(old)


class PrefixCache:
    """Host-side prefix index over the shared KV page pool.

    Prompts are hashed at page granularity with a *chained* digest:
    ``h_i = sha1(h_{i-1} || tokens[i*ps : (i+1)*ps])``, so a page's hash
    commits to every token before it and equal chains imply equal
    logical prefixes (sha1 collisions aside — python ``hash()`` would
    serve wrong tokens on collision, a cryptographic digest won't).
    ``register()`` maps a chain digest to the physical page holding that
    page's KV once the page is fully written with prompt tokens;
    ``lookup()`` walks a new prompt's chain and returns the longest run
    of fully-indexed pages, which admission attaches to the new slot's
    block table (refcounted — the pages are never written by the sharer;
    a write landing inside a shared page triggers copy-on-write first).

    Pages stay indexed while live (rc >= 1) and move to the allocator's
    *cached* pool when their last holder releases them; a cached page is
    reclaimed (and unindexed, via ``on_evict``) only when the free list
    runs dry. ``policy="lru"`` evicts the page whose last release is
    oldest; ``policy="fifo"`` evicts in registration order.
    """

    def __init__(self, alloc: PageAllocator, page_size: int,
                 policy: str = "lru"):
        if policy not in ("lru", "fifo"):
            raise ValueError(f"unknown prefix eviction policy {policy!r}")
        self._alloc = alloc
        self.page_size = page_size
        self.policy = policy
        self._index: Dict[bytes, int] = {}       # chain digest -> page
        self._hash_of: Dict[int, bytes] = {}     # page -> chain digest
        self._reg_seq: Dict[int, int] = {}       # page -> registration no.
        self._seq = 0
        alloc.retain = self._retain
        alloc.on_evict = self._on_evict
        alloc.evict_choice = self._evict_choice

    # ---- allocator seams --------------------------------------------
    def _retain(self, page: int) -> bool:
        return page in self._hash_of

    def _on_evict(self, page: int) -> None:
        h = self._hash_of.pop(page)
        del self._index[h]
        del self._reg_seq[page]

    def _evict_choice(self) -> int:
        cached = self._alloc._cached
        if self.policy == "fifo":
            return min(cached, key=lambda p: self._reg_seq[p])
        return next(iter(cached))       # dict order == release recency

    # ---- hashing ----------------------------------------------------
    def chain(self, tokens: np.ndarray) -> List[bytes]:
        """Chained page digests of every *full* page of ``tokens``."""
        import hashlib
        ps = self.page_size
        toks = np.ascontiguousarray(np.asarray(tokens), np.int32)
        h, out = b"", []
        for i in range(len(toks) // ps):
            h = hashlib.sha1(h + toks[i * ps:(i + 1) * ps].tobytes()) \
                .digest()
            out.append(h)
        return out

    # ---- index ------------------------------------------------------
    def lookup(self, tokens: np.ndarray) -> List[int]:
        """Longest indexed page run covering a prefix of ``tokens``."""
        pages = []
        for h in self.chain(tokens):
            page = self._index.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def register(self, digests: Sequence[bytes],
                 pages: Sequence[int]) -> None:
        """Index ``pages[i]`` (fully written with the tokens digest
        ``digests[i]`` commits to) for future lookups. A digest already
        indexed keeps its first page — two slots racing the same prompt
        each keep their private copy; one gets shared from now on."""
        for h, p in zip(digests, pages):
            if h in self._index or p in self._hash_of:
                continue
            self._index[h] = p
            self._hash_of[p] = h
            self._reg_seq[p] = self._seq
            self._seq += 1

    def unindex(self, page: int) -> None:
        """Drop ``page`` from the index (it is about to be written in
        place by its sole holder); it re-registers — same digest, same
        contents — once the holder's writes are flushed."""
        h = self._hash_of.pop(page, None)
        if h is not None:
            del self._index[h]
            del self._reg_seq[page]

    def __len__(self) -> int:
        return len(self._index)


class ServingEngine:
    """Continuous-batching engine over one model + params (greedy decode)."""

    def __init__(self, model: Model, params: Any, max_batch: int = 8,
                 max_len: int = 128, decode_block: int = 16,
                 min_bucket: int = 8, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 chunk_threshold: Optional[int] = None,
                 stage_slots: int = 0, admission: str = "worstcase",
                 preempt_policy: str = "slack",
                 prefix_cache: bool = False, prefix_evict: str = "lru",
                 stream: bool = False):
        self.model = model
        self.params = params
        # token streaming: when on, every harvest appends newly generated
        # tokens to a partial-output buffer (drain_partial_outputs) and
        # stamps each request's first-token wall time. Off by default so
        # non-streaming callers never accumulate an undrained buffer.
        self.stream = bool(stream)
        self._partial: List[Tuple[Request, List[int], float]] = []
        self.max_batch = max_batch
        self.max_len = max_len
        self.decode_block = decode_block
        self.min_bucket = min_bucket
        # MoE expert capacity is a function of the co-batched token count,
        # so grouped admission could change token-drop decisions vs a
        # serial run; admit MoE prompts one per dispatch to stay exact.
        self._group_admit = model.cfg.family != "moe"
        # Chunked prefill (and in-segment admission, which reuses the same
        # teacher-forcing path) restarts a slot from the family's empty
        # decode state (``Model.empty_state`` — all-zeros except xLSTM's
        # -inf stabilizers). Families whose prefill computes encoder KV
        # (audio/vlm) admit whole prompts. MoE is excluded too: its
        # expert-capacity keep/drop decisions depend on the co-batched
        # token set, so feeding prompt tokens inside the shared decode
        # batch would diverge from the solo prefill the engine otherwise
        # guarantees (see _group_admit).
        self._chunk_ok = model.cfg.family in ("dense", "hybrid", "ssm")
        self.chunk_threshold = \
            chunk_threshold if self._chunk_ok else None
        # in-segment admission: capacity of the device staging ring
        # (0 = boundary-only admission); clamped off with chunking since
        # staged prompts teacher-force through the decode segment
        self.stage_slots = int(stage_slots) if self._chunk_ok and \
            stage_slots else 0
        self.stats: Dict[str, int] = {
            "prefill_traces": 0, "decode_traces": 0, "chunk_traces": 0,
            "prefill_dispatches": 0, "decode_dispatches": 0,
            "decode_steps": 0, "tokens_generated": 0, "admitted": 0,
            "chunk_admits": 0, "peak_concurrency": 0,
            "staged": 0, "inseg_admissions": 0,
            "busy_slot_steps": 0, "bubble_slot_steps": 0,
            "preemptions": 0, "preempt_readmits": 0, "pressure_stalls": 0,
            "prefix_hits": 0, "prefix_pages_reused": 0, "cow_copies": 0,
            "evictions": 0, "prefix_tokens_skipped": 0,
        }
        shapes = model.cache_shapes(max_batch, max_len, enc_len=max_len)
        # Per-leaf batch axis, found by diffing cache shapes at two batch
        # sizes (family-agnostic: attention caches, SSM/conv states, and
        # grouped VLM layouts all place batch differently); per-leaf
        # sequence axis likewise by diffing two max_lens (-1 for the O(1)
        # recurrent states, which have none and are never paged).
        s2 = model.cache_shapes(2, max_len, enc_len=max_len)
        s3 = model.cache_shapes(3, max_len, enc_len=max_len)
        self._batch_axes = jax.tree.map(
            lambda a, b: next(i for i, (x, y) in
                              enumerate(zip(a.shape, b.shape)) if x != y),
            s2, s3)
        l2 = model.cache_shapes(2, max_len + 8, enc_len=max_len + 8)
        self._seq_axes = jax.tree.map(
            lambda a, b: next((i for i, (x, y) in
                               enumerate(zip(a.shape, b.shape)) if x != y),
                              -1),
            s2, l2)
        # ----- paged layout -------------------------------------------
        self.page_size = page_size
        if page_size is not None:
            if max_len % page_size != 0:
                raise ValueError(f"max_len {max_len} not a multiple of "
                                 f"page_size {page_size}")
            self.pages_per_slot = max_len // page_size
            self.n_pages = (max_batch * self.pages_per_slot
                            if n_pages is None else n_pages)
            pageable = any(s != -1 for s in jax.tree.leaves(self._seq_axes))
        else:
            pageable = False
        attn_impl = getattr(model.cfg, "attention_impl", "xla")
        if pageable:
            self._alloc: Optional[PageAllocator] = \
                PageAllocator(self.n_pages, page_size)
            # block-table mirror handed to every device dispatch; the
            # sentinel n_pages drops writes / clamps (masked) reads.
            # The fused Pallas update+attend kernel has no write
            # suppression: instead the pool carries one extra *trash*
            # page at physical index n_pages — exactly the sentinel
            # value — so inactive slots' writes land there harmlessly.
            # The XLA/view path keeps the exact-size pool (scatter uses
            # drop semantics).
            self._pool_pages = self.n_pages + \
                (1 if attn_impl.startswith("pallas") else 0)
            self._bt = KV.sentinel_block_table(
                max_batch, self.pages_per_slot, self.n_pages)
            self._cache = jax.tree.map(
                lambda s, bax, sax: jnp.zeros(
                    self._pool_shape(s.shape, bax, sax), s.dtype),
                shapes, self._batch_axes, self._seq_axes)
        else:
            # contiguous layout — also the path for attention-free
            # families (pure-recurrent xLSTM), whose O(1) states have
            # nothing to page regardless of the knob
            if page_size is None:
                self.pages_per_slot = 0
                self.n_pages = 0
            self._pool_pages = 0
            self._alloc = None
            self._bt = None
            self._cache = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        self._paged = self._bt is not None
        # ----- admission discipline -----------------------------------
        if admission not in ("worstcase", "optimistic"):
            raise ValueError(f"unknown admission mode {admission!r}")
        if preempt_policy not in ("slack", "lru"):
            raise ValueError(f"unknown preempt policy {preempt_policy!r}")
        # Optimistic admission needs (a) the paged layout — pressure is a
        # page-pool phenomenon — and (b) a family whose teacher-forced
        # decode is exact from the empty state, because recovery replays
        # the preempted prefix through the chunked-prefill seat. Anything
        # else clamps back to worst-case (forced ``preempt()`` still works
        # for any chunk-capable family).
        self.admission = admission if (self._alloc is not None and
                                       self._chunk_ok) else "worstcase"
        self.preempt_policy = preempt_policy
        # ----- prefix cache -------------------------------------------
        # Page-granular prefix sharing needs (a) the paged layout, (b)
        # the teacher-forced seat (a hit resumes the prompt at its first
        # uncached token), and (c) *every* cache leaf position-addressable
        # — an O(1) recurrent state (SSM/conv cells, hybrid's ssm layers)
        # summarizes the whole prefix and cannot be recovered from shared
        # KV pages, so those families clamp the knob off and stay exact.
        all_paged = all(s != -1 for s in jax.tree.leaves(self._seq_axes))
        self._prefix: Optional[PrefixCache] = None
        if prefix_cache and self._paged and self._chunk_ok and all_paged:
            self._prefix = PrefixCache(self._alloc, page_size,
                                       policy=prefix_evict)
        # per-slot registration frontier: prompt pages [0, _reg_upto[s])
        # of slot s are already indexed; the chain digests of the slot's
        # seated token row are precomputed at seat time
        self._reg_upto = np.zeros((max_batch,), np.int64)
        self._seat_digests: List[List[bytes]] = [[] for _ in
                                                 range(max_batch)]
        # ----- device mirrors -----------------------------------------
        # The decode segment gathers each slot's KV view from the page
        # pool once at entry and scatters the written span back at exit
        # (XLA layouts), so the per-step loop body is the *contiguous*
        # program: paged indirection costs two transfers per segment
        # instead of two gathers per step. Pallas attention instead runs
        # a fused update+attend kernel over the pool (see
        # kernels.decode_attention.fused_paged_decode_attention).
        self._view_decode = self._paged and \
            not attn_impl.startswith("pallas")
        # block-table upload coalescing: the device copy is invalidated
        # only when a host-side write actually changes self._bt, so
        # steady-state segments reuse the resident array
        self._bt_dev = None
        # idle staging ring reuse: when nothing is staged the ring args
        # are all-zero / all-sentinel constants — upload them once
        self._ring0 = None
        # Per-leaf empty-state rows (batch axis moved to front, batch=1):
        # the slot-reset constant for chunked admission and the fused
        # loop's in-segment refill. Sequence-carrying leaves never need a
        # reset (their positions are rewritten before any masked read), so
        # they get a dummy scalar the reset paths skip by seq axis.
        if model.empty_state is not None:
            empty1 = model.empty_state(1, max_len, enc_len=max_len)
        else:
            s1 = model.cache_shapes(1, max_len, enc_len=max_len)
            empty1 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), s1)
        self._reset_rows = jax.tree.map(
            lambda e, bax, sax: (jnp.moveaxis(jnp.asarray(e), bax, 0)
                                 if sax == -1 else jnp.zeros((), e.dtype)),
            empty1, self._batch_axes, self._seq_axes)
        self._tok = jnp.zeros((max_batch, 1), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        self._rem = jnp.zeros((max_batch,), jnp.int32)
        # chunked-prefill staging: per-slot prompt buffer + prompt length
        # (0 = slot admitted via prefill, nothing left to feed)
        self._plen = jnp.zeros((max_batch,), jnp.int32)
        self._pbuf = jnp.zeros((max_batch, max_len), jnp.int32)
        self._prefill_fns: Dict[int, Any] = {}
        self._decode_fn = None
        self._chunk_fn = None
        self._cow_fn = None
        # open-loop state: persists across submit()/step() calls so
        # requests can arrive while earlier ones are mid-decode
        self._pending: deque = deque()
        self._slot_req: List[Optional[Request]] = [None] * max_batch
        self._gen: Dict[int, List[int]] = {}
        self._free: List[int] = list(range(max_batch))[::-1]
        self._slot_pos = np.zeros((max_batch,), np.int64)
        self._completed: List[Request] = []
        # staging ring (in-segment admission): FIFO of
        # (request, allocator ticket, block-table row) awaiting a freed
        # slot inside a fused segment; mirrors the device ring each step
        self._staged: deque = deque()
        self._stage_seq = 0
        # preempted requests parked host-side (``_Parked``), FIFO; they
        # re-admit ahead of pending work via the chunked-prefill seat
        self._preempted: deque = deque()
        # EWMA of per-decode-step wall time: the slack policy's estimate
        # of a request's remaining service time (positions left x this)
        self._step_est = 0.0

    def _pool_shape(self, dims: Tuple[int, ...], bax: int, sax: int):
        """Contiguous leaf shape -> shared-pool shape: drop the batch axis,
        split the sequence axis into (n_pages, page_size). State leaves
        (sax == -1) keep their slot-indexed shape."""
        if sax == -1:
            return dims
        assert bax < sax, (dims, bax, sax)
        return (dims[:bax] + dims[bax + 1:sax]
                + (self._pool_pages, self.page_size) + dims[sax + 1:])

    def _n_positions(self, r: Request) -> int:
        """KV positions a request writes over its lifetime: the prompt plus
        one per generated token except the last (never fed back)."""
        return len(r.prompt) + max(r.max_new_tokens, 1) - 1

    # ------------------------------------------------------------------
    # compiled programs (keyed on (bucket_batch, bucket_len) shape)
    def _get_prefill(self, bucket: int, nbatch: int):
        key = (nbatch, bucket)
        fn = self._prefill_fns.get(key)
        if fn is not None:
            return fn
        model, cfg = self.model, self.model.cfg
        baxes, saxes = self._batch_axes, self._seq_axes
        paged, ps = self._paged, self.page_size

        def prefill_admit(params, cache, tok, pos, rem, plen, tokens,
                          lengths, slots, max_news, page_rows=None):
            # tokens: (nbatch, bucket); lengths/slots/max_news: (nbatch,).
            # Padding rows carry slot == max_batch: out-of-bounds scatter
            # indices are dropped, so they touch no live slot. In paged
            # mode page_rows (nbatch, ceil(bucket/ps)) routes each leaf's
            # cache slice into the slot's pages (sentinel rows drop —
            # bucket padding past the allocated pages never lands).
            self.stats["prefill_traces"] += 1   # Python side effect: runs
            batch = {"tokens": tokens,          # once per (re)trace only
                     "length": lengths}
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros((nbatch, bucket, cfg.d_model),
                                            cfg.dtype)
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (nbatch, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
            logits, pcache = model.prefill(params, batch)
            firsts = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

            def insert(slot_leaf, new_leaf, bax):
                pads = [(0, 0) if i == bax else (0, t - s)
                        for i, (s, t) in enumerate(zip(new_leaf.shape,
                                                       slot_leaf.shape))]
                new_leaf = jnp.pad(new_leaf, pads).astype(slot_leaf.dtype)
                arr = jnp.moveaxis(slot_leaf, bax, 0)
                rows = jnp.moveaxis(new_leaf, bax, 0)
                arr = arr.at[slots].set(rows, mode="drop")
                return jnp.moveaxis(arr, 0, bax)

            def insert_paged(pool_leaf, new_leaf, bax, sax):
                # page-shape the slice: split its sequence axis into
                # (n_pages_of_bucket, page_size) rows, then scatter each
                # row to its block-table page (shared pool, batch-free)
                if sax == -1:
                    return insert(pool_leaf, new_leaf, bax)
                n_rows = page_rows.shape[1]
                new = jnp.moveaxis(new_leaf, bax, 0)    # (nb, .., S@sax, ..)
                padspec = [(0, 0)] * new.ndim
                padspec[sax] = (0, n_rows * ps - new.shape[sax])
                new = jnp.pad(new, padspec)
                new = new.reshape(new.shape[:sax] + (n_rows, ps)
                                  + new.shape[sax + 1:])
                new = jnp.moveaxis(new, sax, 1)         # (nb, P_b, .., ps, ..)
                new = new.reshape((nbatch * n_rows,) + new.shape[2:])
                pool = jnp.moveaxis(pool_leaf, sax - 1, 0)
                pool = pool.at[page_rows.reshape(-1)].set(
                    new.astype(pool.dtype), mode="drop")
                return jnp.moveaxis(pool, 0, sax - 1)

            if paged:
                cache = jax.tree.map(insert_paged, cache, pcache,
                                     baxes, saxes)
            else:
                cache = jax.tree.map(insert, cache, pcache, baxes)
            tok = tok.at[slots].set(firsts[:, None], mode="drop")
            pos = pos.at[slots].set(lengths, mode="drop")
            rem = rem.at[slots].set(max_news - 1, mode="drop")
            plen = plen.at[slots].set(jnp.zeros_like(max_news), mode="drop")
            return cache, tok, pos, rem, plen, firsts

        fn = jax.jit(prefill_admit)
        self._prefill_fns[key] = fn
        return fn

    def _get_chunk_admit(self):
        """Compiled chunked admission: stage the full prompt in the slot's
        device prompt buffer (no prefill dispatch) and reset the slot's
        recurrent state rows; the decode segment teacher-forces the prompt
        from there, ``decode_block`` tokens per segment."""
        if self._chunk_fn is not None:
            return self._chunk_fn
        baxes, saxes = self._batch_axes, self._seq_axes
        reset_rows = self._reset_rows
        max_len = self.max_len

        n_slots = self.max_batch

        def chunk_admit(cache, tok, pos, rem, plen, pbuf, slot, row,
                        plen_v, max_new, start):
            # slot/plen_v/max_new/start: (1,); row: (1, max_len). start
            # is the first position the seat actually feeds: 0 for plain
            # chunked admission and preemption replay, the first uncached
            # token for a prefix-cache hit (the covered prefix's KV is
            # already resident in the slot's attached pages).
            self.stats["chunk_traces"] += 1
            # KV leaves need no reset: a position is always rewritten by
            # this slot before any masked read can include it. O(1) state
            # leaves carry the previous occupant's final state and must
            # restart from the family's empty state (zeros, except e.g.
            # xLSTM's -inf stabilizers) — same primitive the fused loop's
            # in-segment refill uses, with a one-hot slot mask.
            take = jnp.arange(n_slots) == slot[0]
            cache = jax.tree.map(
                lambda leaf, bax, sax, empty_row:
                    leaf if sax != -1
                    else KV.reset_slot_rows(leaf, bax, take, empty_row),
                cache, baxes, saxes, reset_rows)
            first = jnp.take_along_axis(
                row, jnp.clip(start, 0, max_len - 1)[:, None], axis=1)
            tok = tok.at[slot].set(first)
            pos = pos.at[slot].set(start)
            rem = rem.at[slot].set(max_new)
            plen = plen.at[slot].set(plen_v)
            pbuf = pbuf.at[slot].set(row)
            return cache, tok, pos, rem, plen, pbuf

        self._chunk_fn = jax.jit(chunk_admit)
        return self._chunk_fn

    def _get_decode(self):
        if self._decode_fn is not None:
            return self._decode_fn
        model, steps, slots = self.model, self.decode_block, self.max_batch
        paged, max_len = self._paged, self.max_len
        view = self._view_decode
        R = max(self.stage_slots, 1)      # device ring capacity (static)
        max_comps = slots + R             # completion-log capacity
        baxes, saxes = self._batch_axes, self._seq_axes
        reset_rows = self._reset_rows

        def decode_segment(params, cache, tok, pos, rem, plen, pbuf,
                           ring_tok, ring_plen, ring_new, n_stage,
                           bt=None, ring_bt=None):
            # ring_tok: (R, max_len) staged prompt rows; ring_plen /
            # ring_new: (R,) prompt lengths and max_new budgets; n_stage:
            # scalar count of valid ring entries (0 disables refill);
            # ring_bt: (R, pages_per_slot) pre-reserved block-table rows.
            self.stats["decode_traces"] += 1
            slot_ids = jnp.arange(slots, dtype=jnp.int32)
            pool = cache
            if view:
                # Segment-resident views (XLA attention): gather each
                # slot's contiguous KV view from the page pool once, run
                # the *contiguous* decode program over it for the whole
                # segment, and scatter only the written span [entry pos,
                # exit pos) back through the (final) block table at exit.
                # Per-step paged indirection — a pool gather plus a pool
                # scatter per layer per token — disappears from the loop
                # body entirely, which is what closes the paged tok/s
                # gap; the in-loop math is bit-identical to the
                # contiguous engine because it *is* the same program on
                # the same shapes.
                bt0 = jnp.asarray(bt)
                cache = jax.tree.map(
                    lambda leaf, bax, sax: leaf if sax == -1
                    else KV.gather_pool_view(leaf, bt0, bax, sax),
                    pool, baxes, saxes)

            def cond(st):
                return (st["i"] < steps) & jnp.any(st["rem"] > 0)

            def body(st):
                i, cache = st["i"], st["cache"]
                tok, pos, rem = st["tok"], st["pos"], st["rem"]
                plen, pbuf = st["plen"], st["pbuf"]
                bt_c = st.get("bt")
                active = rem > 0
                dcache = dict(cache, bt=bt_c) if (paged and not view) \
                    else cache
                logits, dcache = model.decode(params, dcache, tok, pos)
                if paged and not view:
                    dcache = {k: v for k, v in dcache.items() if k != "bt"}
                cache = dcache
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                # chunked prefill: while prompt tokens remain, feed the
                # next one instead of the sampled token and emit nothing
                feeding = (pos + 1) < plen
                pnext = jnp.take_along_axis(
                    pbuf, jnp.clip(pos + 1, 0, max_len - 1)[:, None],
                    axis=1)[:, 0]
                nxt = jnp.where(feeding, pnext, nxt)
                emit = jnp.where(active & ~feeding, nxt, -1)
                out = lax.dynamic_update_slice(st["out"], emit[:, None],
                                               (0, i))
                tok = jnp.where(active[:, None], nxt[:, None], tok)
                pos = jnp.where(active, pos + 1, pos)
                rem = jnp.where(active & ~feeding, rem - 1, rem)
                # ---- completion log + in-segment slot refill ----------
                # Freshly finished slots are logged (slot, step) in slot
                # order; the first `avail` of them pull the next staged
                # requests (FIFO: j-th admitted completion of the segment
                # takes ring entry j), resetting the slot inside the loop
                # so the dispatch retires multiple requests per slot.
                fin = active & ~feeding & (rem == 0)
                nfin = jnp.sum(fin.astype(jnp.int32))
                head = st["head"]
                avail = n_stage - head
                rank = jnp.cumsum(fin.astype(jnp.int32)) - 1
                adm = fin & (rank < avail)
                src = jnp.clip(head + rank, 0, R - 1)
                log_idx = jnp.where(fin, st["n_comp"] + rank, max_comps)
                comp_slot = st["comp_slot"].at[log_idx].set(
                    slot_ids, mode="drop")
                comp_step = st["comp_step"].at[log_idx].set(i, mode="drop")
                comp_adm = st["comp_adm"].at[log_idx].set(
                    adm.astype(jnp.int32), mode="drop")
                rows = jnp.take(ring_tok, src, axis=0)     # (B, max_len)
                tok = jnp.where(adm[:, None], rows[:, :1], tok)
                pbuf = jnp.where(adm[:, None], rows, pbuf)
                pos = jnp.where(adm, 0, pos)
                rem = jnp.where(adm, jnp.take(ring_new, src), rem)
                plen = jnp.where(adm, jnp.take(ring_plen, src), plen)
                cache = jax.tree.map(
                    lambda leaf, bax, sax, row:
                        leaf if sax != -1
                        else KV.reset_slot_rows(leaf, bax, adm, row),
                    cache, baxes, saxes, reset_rows)
                new = dict(
                    i=i + 1, cache=cache, tok=tok, pos=pos, rem=rem,
                    plen=plen, pbuf=pbuf, out=out,
                    head=head + jnp.minimum(nfin, jnp.maximum(avail, 0)),
                    comp_slot=comp_slot, comp_step=comp_step,
                    comp_adm=comp_adm, n_comp=st["n_comp"] + nfin,
                    busy=st["busy"] + jnp.sum(active.astype(jnp.int32)))
                if paged:
                    new["bt"] = jnp.where(adm[:, None],
                                          jnp.take(ring_bt, src, axis=0),
                                          bt_c)
                if view:
                    # a refilled slot restarts at position 0: its whole
                    # written span flushes through the ring's block-table
                    # row at exit (the previous occupant's in-view tail
                    # is never written back — its pages are released at
                    # harvest and may already be re-handed)
                    new["seg"] = jnp.where(adm, 0, st["seg"])
                return new

            st0 = dict(i=jnp.int32(0), cache=cache, tok=tok, pos=pos,
                       rem=rem, plen=plen, pbuf=pbuf,
                       out=jnp.full((slots, steps), -1, jnp.int32),
                       head=jnp.int32(0),
                       comp_slot=jnp.zeros((max_comps,), jnp.int32),
                       comp_step=jnp.zeros((max_comps,), jnp.int32),
                       comp_adm=jnp.zeros((max_comps,), jnp.int32),
                       n_comp=jnp.int32(0), busy=jnp.int32(0))
            if paged:
                st0["bt"] = jnp.asarray(bt)
            if view:
                st0["seg"] = pos
            st = lax.while_loop(cond, body, st0)
            out_cache = st["cache"]
            if view:
                # flush each slot's written span back to the page pool
                # through its *final* block table (in-segment refills
                # switched rows mid-loop); sentinel rows drop, so
                # preempted/idle slots touch nothing
                out_cache = jax.tree.map(
                    lambda pool_leaf, view_leaf, bax, sax:
                        view_leaf if sax == -1
                        else KV.scatter_pool_view(
                            pool_leaf, view_leaf, st["bt"], bax, sax,
                            st["seg"], st["pos"]),
                    pool, out_cache, baxes, saxes)
            return (out_cache, st["tok"], st["pos"], st["rem"],
                    st["plen"], st["pbuf"], st["out"], st["comp_slot"],
                    st["comp_step"], st["comp_adm"], st["n_comp"],
                    st["busy"], st["i"])

        if paged:
            self._decode_fn = jax.jit(decode_segment)
        else:
            self._decode_fn = jax.jit(
                lambda params, cache, tok, pos, rem, plen, pbuf,
                rtok, rplen, rnew, n_stage:
                decode_segment(params, cache, tok, pos, rem, plen, pbuf,
                               rtok, rplen, rnew, n_stage))
        return self._decode_fn

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens: Sequence[int] = (),
               include_decode: bool = True) -> None:
        """Compile prefill executables for the (batch, length) buckets
        covering ``prompt_lens`` (plus the minimum bucket) and the decode
        segment.

        Warmup calls run against the live state with every scatter index
        out of bounds (dropped), so engine state is untouched; subsequent
        serving on these buckets never recompiles.
        """
        lens = [n for n in prompt_lens
                if self.chunk_threshold is None or n <= self.chunk_threshold]
        buckets = {bucket_len(max(n, 1), self.min_bucket, self.max_len)
                   for n in lens + [1]}       # chunked lens never prefill
        nbatches = {1, self.max_batch} if self._group_admit else {1}
        for b in sorted(buckets):
            for nb in sorted(nbatches):
                if (nb, b) in self._prefill_fns:
                    continue        # already compiled; skip the dummy run
                fn = self._get_prefill(b, nb)
                args = [self.params, self._cache, self._tok, self._pos,
                        self._rem, self._plen, np.zeros((nb, b), np.int32),
                        np.ones((nb,), np.int32),
                        np.full((nb,), self.max_batch, np.int32),
                        np.ones((nb,), np.int32)]
                if self._paged:
                    args.append(np.full((nb, self._page_rows_for(b)),
                                        self.n_pages, np.int32))
                out = fn(*args)
                jax.block_until_ready(out[-1])
        if include_decode and self._decode_fn is None:
            fn = self._get_decode()
            R = max(self.stage_slots, 1)
            args = [self.params, self._cache, self._tok, self._pos,
                    jnp.zeros((self.max_batch,), jnp.int32), self._plen,
                    self._pbuf, np.zeros((R, self.max_len), np.int32),
                    np.zeros((R,), np.int32), np.zeros((R,), np.int32),
                    np.int32(0)]
            if self._paged:
                args += [self._bt, KV.sentinel_block_table(
                    R, self.pages_per_slot, self.n_pages)]
            out = fn(*args)
            jax.block_until_ready(out[-1])
        if (self.chunk_threshold is not None
                or self.admission == "optimistic"
                or self._prefix is not None) and \
                self._chunk_fn is None:
            # optimistic engines seat preempted prefixes through the chunk
            # path even with chunking off, and prefix-cache hits seat
            # through it too: compile it out of band in both cases
            fn = self._get_chunk_admit()
            out = fn(self._cache, self._tok, self._pos, self._rem,
                     self._plen, self._pbuf,
                     np.full((1,), self.max_batch, np.int32),
                     np.zeros((1, self.max_len), np.int32),
                     np.zeros((1,), np.int32), np.zeros((1,), np.int32),
                     np.zeros((1,), np.int32))
            jax.block_until_ready(out[1])

    def _page_rows_for(self, bucket: int) -> int:
        """Block-table rows a bucket-wide prefill slice spans."""
        return -(-bucket // self.page_size)

    # ------------------------------------------------------------------
    def _admit_group(self, bucket: int, rs: List[Request],
                     slots: List[int]) -> np.ndarray:
        """One prefill dispatch admitting same-bucket requests into slots.

        Admit batches are bucketed to {1, max_batch} so the executable
        count stays at <= 2 per prompt bucket; padding rows point their
        scatter index past the last slot and are dropped. In paged mode
        each request's prompt pages are allocated here (its block-table
        row was reserved at pop time) and the prefill scatters page-shaped
        cache slices through them.
        """
        m = len(rs)
        nb = 1 if m == 1 else self.max_batch
        tokens = np.zeros((nb, bucket), np.int32)
        lengths = np.ones((nb,), np.int32)
        slot_idx = np.full((nb,), self.max_batch, np.int32)
        max_news = np.ones((nb,), np.int32)
        for j, (r, s) in enumerate(zip(rs, slots)):
            tokens[j, : len(r.prompt)] = r.prompt       # right-pad
            lengths[j] = len(r.prompt)
            slot_idx[j] = s
            max_news[j] = max(r.max_new_tokens, 1)
        fn = self._get_prefill(bucket, nb)
        args = [self.params, self._cache, self._tok, self._pos, self._rem,
                self._plen, tokens, lengths, slot_idx, max_news]
        if self._paged:
            n_rows = self._page_rows_for(bucket)
            page_rows = np.full((nb, n_rows), self.n_pages, np.int32)
            for j, (r, s) in enumerate(zip(rs, slots)):
                self._grow_slot(s, len(r.prompt))
                page_rows[j] = self._bt[s, :n_rows]
            args.append(page_rows)
        (self._cache, self._tok, self._pos, self._rem, self._plen,
         firsts) = fn(*args)
        self.stats["prefill_dispatches"] += 1
        self.stats["admitted"] += m
        if self._prefix is not None:
            for r, s in zip(rs, slots):
                self._seat_digests[s] = self._prefix.chain(r.prompt)
                self._reg_upto[s] = 0
        return np.asarray(firsts)[:m]

    def _lookup_attach(self, slot: int,
                       tokens: np.ndarray) -> Optional[int]:
        """Prefix-cache lookup for a request about to be seated in
        ``slot`` (which already holds its reservation): attach the hit
        pages to the slot's block table (refcounted) and return the
        teacher-forcing start position — the first uncached token — or
        ``None`` on a miss.

        When the hit covers every full page of the prompt, the seat
        still rewrites position ``plen - 1`` (its logits produce the
        first output token), which lands *inside* the last shared page:
        that page is copy-on-write duplicated first — unless this slot
        is its only holder, in which case it is written in place and
        unindexed until the rewrite lands (no sharer can appear mid-
        flight, keeping "no write to a page with refcount > 1" exact).
        """
        if self._prefix is None:
            return None
        hit = self._prefix.lookup(tokens)
        if not hit:
            return None
        ps = self.page_size
        plen = len(tokens)
        self._alloc.attach(slot, hit)
        self._bt[slot, :len(hit)] = hit
        self._bt_dev = None
        start = min(len(hit) * ps, plen - 1)
        if len(hit) * ps >= plen:
            if self._alloc.refcount(hit[-1]) > 1:
                old, new = self._alloc.cow(slot, len(hit) - 1)
                self._bt[slot, len(hit) - 1] = new
                self._copy_page(old, new)
                self.stats["cow_copies"] += 1
            else:
                self._prefix.unindex(hit[-1])
        self.stats["prefix_hits"] += 1
        self.stats["prefix_pages_reused"] += len(hit)
        self.stats["prefix_tokens_skipped"] += start
        return start

    def _grow_slot(self, slot: int, n_positions: int) -> None:
        """Extend ``slot``'s block table to cover positions [0, n)."""
        held = len(self._alloc.pages_of(slot))
        new = self._alloc.cover(slot, n_positions)
        if new:
            self._bt[slot, held:held + len(new)] = new
            self._bt_dev = None

    def _copy_page(self, src: int, dst: int) -> None:
        """Device half of copy-on-write: duplicate physical page ``src``
        into ``dst`` across every paged cache leaf (one jitted scatter,
        page ids traced so all copies share the executable)."""
        if self._cow_fn is None:
            saxes = self._seq_axes

            def cow_copy(cache, s, d):
                return jax.tree.map(
                    lambda leaf, sax: leaf if sax == -1
                    else KV.copy_pool_page(leaf, s, d, sax),
                    cache, saxes)

            self._cow_fn = jax.jit(cow_copy)
        self._cache = self._cow_fn(self._cache, np.int32(src),
                                   np.int32(dst))

    def _seat_prefix(self, slot: int, prefix: np.ndarray,
                     max_new: int, start: int = 0) -> None:
        """Seat a token prefix in ``slot`` for teacher-forced replay: the
        prefix goes to the slot's device prompt buffer, the slot's state
        rows reset to the family's empty state, and the next segments feed
        it ``decode_block`` tokens per dispatch before emitting ``max_new``
        greedy tokens. The primitive under chunked admission (prefix ==
        prompt) and preemption recovery (prefix == prompt + tokens already
        generated, which makes the continuation bit-identical). A
        prefix-cache hit passes ``start`` > 0: positions [0, start) are
        already resident in the slot's attached pages, so the feed starts
        at the first uncached token."""
        plen = len(prefix)
        row = np.zeros((1, self.max_len), np.int32)
        row[0, :plen] = prefix
        fn = self._get_chunk_admit()
        (self._cache, self._tok, self._pos, self._rem, self._plen,
         self._pbuf) = fn(
            self._cache, self._tok, self._pos, self._rem, self._plen,
            self._pbuf, np.asarray([slot], np.int32), row,
            np.asarray([plen], np.int32),
            np.asarray([max(max_new, 1)], np.int32),
            np.asarray([start], np.int32))
        if self._prefix is not None:
            self._seat_digests[slot] = self._prefix.chain(prefix)
            self._reg_upto[slot] = 0

    def _chunk_seat(self, r: Request, slot: int) -> None:
        """Stage ``r``'s prompt in ``slot``'s device prompt buffer and
        reset the slot's state rows (no prefill dispatch): shared by
        chunked admission and the boundary fallback that seats staged
        requests into freed slots."""
        self._seat_prefix(slot, np.asarray(r.prompt, np.int32),
                          max(r.max_new_tokens, 1))

    def _admit_chunk(self, r: Request, slot: int) -> None:
        """Chunked admission: no prefill dispatch — stage the prompt in
        the slot's device prompt buffer; the next decode segments feed it
        ``decode_block`` tokens at a time."""
        self._chunk_seat(r, slot)
        self.stats["chunk_admits"] += 1
        self.stats["admitted"] += 1

    # ------------------------------------------------------------------
    # open-loop core: submit / step / drain_completions
    @property
    def busy(self) -> bool:
        """True while any request is pending admission, staged for
        in-segment admission, parked after a preemption, or mid-decode."""
        return bool(self._pending) or bool(self._staged) or \
            bool(self._preempted) or \
            any(r is not None for r in self._slot_req)

    def _validate(self, r: Request) -> None:
        if len(r.prompt) + r.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {r.rid}: prompt_len {len(r.prompt)} + max_new "
                f"{r.max_new_tokens} exceeds engine max_len "
                f"{self.max_len}")
        if self._alloc is not None:
            need = self._alloc.pages_needed(self._n_positions(r))
            if need > self.n_pages:
                raise ValueError(
                    f"request {r.rid}: needs {need} pages but the pool "
                    f"holds {self.n_pages}; it could never be admitted")

    def submit(self, r: Request) -> None:
        """Enqueue a request; may be called at any time, including while
        other requests are mid-decode (it joins at the next ``step()``).
        The latency clock starts at ``r.arrival`` (stamped now if unset)."""
        self._validate(r)
        if r.arrival == 0.0:
            r.arrival = time.perf_counter()
        self._pending.append(r)

    def _admit_pending(self) -> None:
        """Fill free slots from the pending queue (grouped by bucket),
        then top up the staging ring for in-segment admission.

        In paged mode admission is additionally gated on free pages: the
        queue head must fit its worst-case page reservation before it (or
        anything behind it — FIFO) is admitted or staged. Prompts longer
        than ``chunk_threshold`` take the chunked path; the rest prefill.
        Staged requests hold their worst-case reservation from staging
        time under a per-request ticket, with their first ``decode_block``
        positions' pages materialized up front — the fused segment that
        pulls them in has no host boundary at which to grow them."""
        now = time.perf_counter()
        # parked (preempted) requests re-admit ahead of everything else:
        # they are the oldest admitted work, they hold zero pages while
        # parked, and seating them first bounds how often the same request
        # gets re-preempted. Recovery teacher-forces the full prefix
        # (prompt + tokens already generated) through the chunked-prefill
        # seat, so the continuation is bit-identical to an uninterrupted
        # run; tokens generated before the preempt are re-credited to the
        # slot's emission list rather than regenerated.
        #
        # Re-admission is deliberately NOT optimistic: it waits until the
        # request's full remaining worst case sits in actually-free pages.
        # Optimistically re-admitting into the still-contended pool that
        # just evicted it is ping-pong — every bounce replays the whole
        # prefix (pure waste) before any new token lands. The hysteresis
        # costs nothing at the peak (initial admits already filled every
        # slot) and converts preempt-thrash into one park per victim.
        while self._preempted and self._free:
            p = self._preempted[0]
            npos = self._n_positions(p.req)
            if self._alloc is not None:
                if self.admission == "optimistic":
                    if self._alloc.pages_needed(npos) > self._alloc.n_avail:
                        break
                elif not self._alloc.can_reserve(npos):
                    break
            self._preempted.popleft()
            slot = self._free.pop()
            start = None
            if self._alloc is not None:
                self._alloc.reserve(slot, npos,
                                    strict=self.admission != "optimistic")
                # the victim's registered prompt pages went to the cached
                # pool when it was preempted, so re-admission usually
                # re-hits the cache and replays only the uncached tail
                start = self._lookup_attach(slot, p.prefix)
                if self.admission == "optimistic":
                    # materialize the first stride now so this pass's
                    # free-page accounting stays exact for the next seat
                    self._grow_slot(slot, min(npos, (start or 0)
                                              + self.decode_block))
            self._seat_prefix(slot, p.prefix,
                              max(p.req.max_new_tokens - len(p.done), 1),
                              start=start or 0)
            self.stats["preempt_readmits"] += 1
            self._gen[slot] = list(p.done)
            self._slot_req[slot] = p.req
            self._slot_pos[slot] = start or 0
        # boundary fallback: seat already-staged requests into free slots
        # the loop never refilled — a slot can come back without an
        # in-loop admission (e.g. a max_new==1 prefill finishes at
        # admission and is swept at harvest), and the staged FIFO precedes
        # everything still in pending. A staged request at a boundary IS a
        # chunk admission whose pages are already reserved.
        while self._staged and self._free:
            r, ticket, bt_row = self._staged.popleft()
            slot = self._free.pop()
            if self._alloc is not None:
                self._alloc.rekey(ticket, slot)
                self._bt[slot, :] = bt_row
                self._bt_dev = None
            r.admitted = now
            self._chunk_seat(r, slot)
            self.stats["admitted"] += 1
            self._gen[slot] = []
            self._slot_req[slot] = r
            self._slot_pos[slot] = 0
        prefills: List[Tuple[Request, int]] = []
        # no new admissions while preempted work waits: a fresh request
        # seated now would take the very pages the parked request is
        # waiting to re-earn (arrival-order inversion + another preempt
        # cycle). The parked queue drains first, always — its head fits
        # the pool by the submit()-time validation.
        while self._pending and self._free and not self._preempted:
            r = self._pending[0]
            npos = self._n_positions(r)
            chunked = self.chunk_threshold is not None and \
                len(r.prompt) > self.chunk_threshold
            if self._alloc is not None:
                if self.admission == "optimistic":
                    # expected usage: a prefill needs its prompt pages at
                    # the dispatch; a chunked prompt only its first
                    # decode_block stride; a prefix-cache hit only the
                    # stride past its cached pages (estimated here, +1
                    # for a possible copy-on-write page). The decode tail
                    # grows lazily — under pressure the grow path
                    # preempts, never wedges.
                    hit_est = len(self._prefix.lookup(r.prompt)) \
                        if self._prefix is not None else 0
                    if hit_est:
                        first = min(npos, hit_est * self.page_size
                                    + self.decode_block)
                        need = self._alloc.pages_needed(first) \
                            - hit_est + 1
                    else:
                        first = min(npos, self.decode_block) if chunked \
                            else len(r.prompt)
                        need = self._alloc.pages_needed(first)
                    if need > self._alloc.n_avail:
                        break
                elif not self._alloc.can_reserve(npos):
                    break
            self._pending.popleft()
            slot = self._free.pop()
            start = None
            if self._alloc is not None:
                self._alloc.reserve(slot, npos,
                                    strict=self.admission != "optimistic")
                start = self._lookup_attach(slot, r.prompt)
                if self.admission == "optimistic":
                    # cover the expected pages now so this pass's free-page
                    # accounting stays exact for the next queue head
                    if start is not None:
                        first = min(npos, start + self.decode_block)
                    self._grow_slot(slot, first)
            r.admitted = now
            if start is not None:
                # cache hit: the covered prefill is skipped entirely —
                # the seat teacher-forces from the first uncached token
                self._seat_prefix(slot, np.asarray(r.prompt, np.int32),
                                  max(r.max_new_tokens, 1), start=start)
                self.stats["admitted"] += 1
                self._gen[slot] = []        # first token comes via emit
                self._slot_req[slot] = r
                self._slot_pos[slot] = start
            elif chunked:
                self._admit_chunk(r, slot)
                self._gen[slot] = []        # first token comes via emit
                self._slot_req[slot] = r
                self._slot_pos[slot] = 0
            else:
                prefills.append((r, slot))
        groups: Dict[int, List[Tuple[Request, int]]] = {}
        for r, s in prefills:
            b = bucket_len(len(r.prompt), self.min_bucket, self.max_len)
            groups.setdefault(b, []).append((r, s))
        for b, pairs in sorted(groups.items()):
            units = [pairs] if self._group_admit else \
                [[p] for p in pairs]
            for unit in units:
                rs = [r for r, _ in unit]
                slots = [s for _, s in unit]
                firsts = self._admit_group(b, rs, slots)
                for r, s, f in zip(rs, slots, firsts):
                    self._gen[s] = [int(f)]
                    self._slot_req[s] = r
                    self._slot_pos[s] = len(r.prompt)
        # ---- staging ring: queue overflow rides into the segment ------
        while self.stage_slots and self._pending and \
                not self._preempted and \
                len(self._staged) < self.stage_slots:
            r = self._pending[0]
            npos = self._n_positions(r)
            if self._alloc is not None:
                if self.admission == "optimistic":
                    if self._alloc.pages_needed(
                            min(npos, self.decode_block)) > \
                            self._alloc.n_avail:
                        break
                elif not self._alloc.can_reserve(npos):
                    break                   # FIFO: nothing jumps the line
            self._pending.popleft()
            ticket = ("stage", self._stage_seq)
            self._stage_seq += 1
            bt_row = None
            if self._alloc is not None:
                self._alloc.reserve(ticket, npos,
                                    strict=self.admission != "optimistic")
                pages = self._alloc.cover(
                    ticket, min(npos, self.decode_block))
                bt_row = np.full((self.pages_per_slot,), self.n_pages,
                                 np.int32)
                bt_row[:len(pages)] = pages
            self._staged.append((r, ticket, bt_row))
            self.stats["staged"] += 1

    # ------------------------------------------------------------------
    # preemption: park / pick victim / relieve pressure
    def _preempt_slot(self, v: int) -> None:
        """Preempt ``v``'s occupant: free its pages, park the request
        host-side with its prompt plus every token generated so far, and
        deactivate the slot on device. Host-boundary only (between
        dispatches)."""
        r = self._slot_req[v]
        done = self._gen.pop(v)[: r.max_new_tokens]
        prefix = np.concatenate([np.asarray(r.prompt, np.int32),
                                 np.asarray(done, np.int32)])
        r.preemptions += 1
        self.stats["preemptions"] += 1
        self._slot_req[v] = None
        self._free.append(v)
        if self._alloc is not None:
            # shared pages only lose this slot's reference; the victim's
            # registered prompt pages stay indexed (cached once idle), so
            # its re-admission usually re-hits the prefix cache
            self._alloc.release(v)
            self._bt[v, :] = self.n_pages
            self._bt_dev = None
        self._preempted.append(_Parked(r, prefix.astype(np.int32),
                                       list(done)))
        # rem == 0 deactivates the slot: the next fused segment neither
        # advances it, emits for it, nor logs a completion for it (and in
        # paged mode its sentinel block-table row drops any KV write)
        self._rem = jnp.asarray(self._rem).at[v].set(0)

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Choose a live slot to preempt (never ``exclude``, the slot
        whose growth triggered the pressure). ``slack`` preempts the
        request that can best afford the round trip: most slack = deadline
        minus elapsed minus estimated remaining service (positions left x
        EWMA step time), no-SLO requests counting as infinite slack, ties
        broken toward never-yet-preempted then longest-remaining — a slot
        mid-way through replaying a preempted prefix resets its position
        counter, so without the preemption-count tie-break it *looks* like
        the longest-remaining candidate and the same request bounces
        between park and replay while fresh requests sail through. ``lru``
        preempts the most recently admitted request (vLLM-style recompute:
        the youngest has the least work to replay)."""
        cands = [s for s, r in enumerate(self._slot_req)
                 if r is not None and s != exclude]
        if not cands:
            return None
        if self.preempt_policy == "lru":
            return max(cands,
                       key=lambda s: (self._slot_req[s].admitted, s))
        now = time.perf_counter()

        def slack(s: int):
            r = self._slot_req[s]
            left = max(self._n_positions(r) - int(self._slot_pos[s]), 1)
            est = left * self._step_est
            sl = float("inf") if r.slo is None \
                else (r.arrival + r.slo) - now - est
            return (sl, -r.preemptions, left, s)

        return max(cands, key=slack)

    def _relieve_pressure(self, protect: int) -> bool:
        """Free pages under pressure, cheapest first: un-stage the newest
        staged request (zero work lost — it returns to the head of
        pending, FIFO preserved), then preempt a live victim. Returns
        False when nothing is left to free."""
        if self._staged:
            r, ticket, _bt_row = self._staged.pop()
            if self._alloc is not None:
                self._alloc.release(ticket)
            self._pending.appendleft(r)
            return True
        v = self._pick_victim(exclude=protect)
        if v is None:
            return False
        self._preempt_slot(v)
        return True

    def preempt(self, slot: int) -> None:
        """Forcibly preempt the request in ``slot`` (fault injection and
        tests; the engine preempts on its own under page pressure): park
        it and free its resources. It re-admits through the teacher-forced
        replay path with a bit-identical continuation. Call between
        ``step()`` boundaries only."""
        if not self._chunk_ok:
            raise ValueError(
                f"family {self.model.cfg.family!r} cannot recover a "
                "preempted request (no teacher-forced replay path)")
        if not 0 <= slot < self.max_batch or self._slot_req[slot] is None:
            raise ValueError(f"slot {slot} is not live")
        self._preempt_slot(slot)

    def _flush_stream(self, slot: int, r: Request, now: float) -> None:
        """Move tokens past the request's streaming cursor into the
        partial-output buffer (no-op unless ``stream=True``). The cursor
        lives on the Request, so a preempted occupant whose generated
        tokens are re-credited at replay never re-streams them."""
        if not self.stream:
            return
        done = self._gen.get(slot)
        if done is None:
            return
        n = min(len(done), r.max_new_tokens)
        if n > r.streamed:
            if r.first_token < 0.0:
                r.first_token = now
            self._partial.append((r, [int(x) for x in done[r.streamed:n]],
                                  now))
            r.streamed = n

    def _retire_slot(self, slot: int, r: Request, now: float) -> None:
        """Finish ``slot``'s current occupant: hand it its tokens, free its
        pages. The caller decides what happens to the slot next (freed, or
        re-occupied by a staged request the segment pulled in)."""
        self._flush_stream(slot, r, now)
        r.tokens = np.asarray(
            self._gen.pop(slot)[: r.max_new_tokens], np.int32)
        r.latency = now - r.arrival
        self.stats["tokens_generated"] += len(r.tokens)
        self._slot_req[slot] = None
        if self._alloc is not None:
            # pages return to the pool the moment a sequence ends (the
            # prefix cache retains any it has indexed, rc permitting)
            self._alloc.release(slot)
            self._bt[slot, :] = self.n_pages
            self._bt_dev = None
        self._completed.append(r)

    def step(self) -> int:
        """One engine step: admit pending requests into free slots (staging
        the overflow into the device ring), run one fused decode segment,
        harvest finished slots — decoding the segment's completion log to
        split each slot's emission row between its successive occupants.
        Returns the number of decode steps executed (0 when idle)."""
        self._admit_pending()
        live = sum(r is not None for r in self._slot_req)
        if live == 0:
            return 0
        self.stats["peak_concurrency"] = max(
            self.stats["peak_concurrency"], live)
        if self._alloc is not None:
            # append pages ahead of the segment: each active slot's pos
            # advances by at most decode_block positions before the next
            # host boundary. Worst-case reservations pre-fund every cover;
            # optimistic admission can find the pool dry here, in which
            # case pressure relief un-stages queued work and then preempts
            # the slackest victim until the grow fits (it always does
            # eventually: a lone validated request fits the pool).
            for s, r in enumerate(self._slot_req):
                if r is None:
                    continue
                cover = min(int(self._slot_pos[s]) + self.decode_block,
                            self._n_positions(r))
                if not self._alloc.can_cover(s, cover):
                    self.stats["pressure_stalls"] += 1
                    while not self._alloc.can_cover(s, cover):
                        if not self._relieve_pressure(protect=s):
                            break
                self._grow_slot(s, cover)
        decode = self._get_decode()
        R = max(self.stage_slots, 1)
        if self._staged:
            ring_tok = np.zeros((R, self.max_len), np.int32)
            ring_plen = np.zeros((R,), np.int32)
            ring_new = np.zeros((R,), np.int32)
            ring_bt = KV.sentinel_block_table(
                R, self.pages_per_slot, self.n_pages) if self._paged \
                else None
            for j, (r, _ticket, bt_row) in enumerate(self._staged):
                ring_tok[j, :len(r.prompt)] = r.prompt
                ring_plen[j] = len(r.prompt)
                ring_new[j] = max(r.max_new_tokens, 1)
                if ring_bt is not None:
                    ring_bt[j] = bt_row
        else:
            # empty-ring steady state: reuse one device-resident zero
            # ring instead of re-uploading fresh host arrays per segment
            if self._ring0 is None:
                self._ring0 = (
                    jnp.zeros((R, self.max_len), jnp.int32),
                    jnp.zeros((R,), jnp.int32),
                    jnp.zeros((R,), jnp.int32),
                    jnp.asarray(KV.sentinel_block_table(
                        R, self.pages_per_slot, self.n_pages))
                    if self._paged else None)
            ring_tok, ring_plen, ring_new, ring_bt = self._ring0
        args = [self.params, self._cache, self._tok, self._pos, self._rem,
                self._plen, self._pbuf, ring_tok, ring_plen, ring_new,
                np.int32(len(self._staged))]
        if self._paged:
            # the block table rides to the device only when a host-side
            # write actually changed it (admission, growth, preemption,
            # COW); steady-state segments reuse the resident copy
            if self._bt_dev is None:
                self._bt_dev = jnp.asarray(self._bt)
            args += [self._bt_dev, ring_bt]
        t_seg = time.perf_counter()
        (self._cache, self._tok, self._pos, self._rem, self._plen,
         self._pbuf, out, comp_slot, comp_step, comp_adm, n_comp,
         busy_steps, n_steps) = decode(*args)
        self.stats["decode_dispatches"] += 1
        out_np = np.asarray(out)                     # the one host sync
        comp_slot = np.asarray(comp_slot)
        comp_step = np.asarray(comp_step)
        comp_adm = np.asarray(comp_adm)
        n_comp = int(n_comp)
        n_steps = int(n_steps)
        if n_steps:
            # EWMA per-step wall time (all slots advance in lockstep):
            # feeds the slack policy's remaining-service estimate
            per = (time.perf_counter() - t_seg) / n_steps
            self._step_est = per if self._step_est == 0.0 \
                else 0.8 * self._step_est + 0.2 * per
        self._slot_pos = np.asarray(self._pos).astype(np.int64)
        self.stats["decode_steps"] += n_steps
        self.stats["busy_slot_steps"] += int(busy_steps)
        self.stats["bubble_slot_steps"] += \
            n_steps * self.max_batch - int(busy_steps)
        now = time.perf_counter()
        # completion log, in segment order: each record closes the slot's
        # current occupant over out[slot, consumed:step+1]; an "admitted"
        # record then seats the next staged request (device admission is
        # FIFO over the ring, mirrored by popping self._staged in order)
        consumed = np.zeros((self.max_batch,), np.int64)
        for j in range(n_comp):
            s = int(comp_slot[j])
            t = int(comp_step[j])
            r = self._slot_req[s]
            row = out_np[s, consumed[s]:t + 1]
            self._gen[s].extend(int(x) for x in row[row >= 0])
            consumed[s] = t + 1
            self._retire_slot(s, r, now)
            if comp_adm[j]:
                nr, ticket, bt_row = self._staged.popleft()
                if self._alloc is not None:
                    self._alloc.rekey(ticket, s)
                    self._bt[s, :] = bt_row
                    self._bt_dev = None
                nr.admitted = now
                self._slot_req[s] = nr
                self._gen[s] = []
                if self._prefix is not None:
                    # staged seats bypass the cache (pages can't attach
                    # mid-segment) but their prompt pages still register
                    self._seat_digests[s] = self._prefix.chain(nr.prompt)
                    self._reg_upto[s] = 0
                self.stats["admitted"] += 1
                self.stats["inseg_admissions"] += 1
            else:
                self._free.append(s)
        for s, r in enumerate(self._slot_req):
            if r is None:
                continue
            row = out_np[s, consumed[s]:]
            self._gen[s].extend(int(x) for x in row[row >= 0])
            self._flush_stream(s, r, now)
        # a prefilled request with max_new == 1 is complete at admission
        # (its only token came from prefill, rem == 0): it never passes
        # through the loop's refill logic, so sweep it here
        rem_np = np.asarray(self._rem)
        for s, r in enumerate(self._slot_req):
            if r is not None and rem_np[s] == 0:
                self._retire_slot(s, r, now)
                self._free.append(s)
        # prefix registration: index every prompt page the segment fully
        # wrote (pos frontier crossed its end). Host bookkeeping only —
        # the pool bytes were produced by this segment's device ops, so
        # any later lookup's gather is ordered after them.
        if self._prefix is not None:
            for s, r in enumerate(self._slot_req):
                if r is None or not self._seat_digests[s]:
                    continue
                done = int(self._reg_upto[s])
                n_ready = min(int(self._slot_pos[s]) // self.page_size,
                              len(self._seat_digests[s]))
                if n_ready > done:
                    self._prefix.register(
                        self._seat_digests[s][done:n_ready],
                        [int(p) for p in self._bt[s, done:n_ready]])
                    self._reg_upto[s] = n_ready
            self.stats["evictions"] = self._alloc.evictions
        return n_steps

    def drain_completions(self) -> List[Request]:
        """Return (and clear) the requests completed since the last drain."""
        out, self._completed = self._completed, []
        return out

    def drain_partial_outputs(self) -> List[Tuple[Request, List[int], float]]:
        """Return (and clear) ``(request, new_tokens, t_wall)`` chunks
        harvested since the last drain (``stream=True`` engines only).
        Chunks for one request appear in emission order, and across all
        drains their concatenation equals ``request.tokens`` exactly."""
        out, self._partial = self._partial, []
        return out

    @property
    def occupancy(self) -> Dict[str, float]:
        """Derived occupancy metrics over all fused segments so far:
        slot-busy fraction (active vs total slot-steps inside segments),
        in-segment admissions per segment, and the absolute bubble (idle
        slot-step) count. ``EngineExecutor`` snapshots deltas of these
        per run into its decision log."""
        busy = self.stats["busy_slot_steps"]
        bubble = self.stats["bubble_slot_steps"]
        segs = self.stats["decode_dispatches"]
        total = busy + bubble
        if self._alloc is not None:
            self.stats["evictions"] = self._alloc.evictions
        return {
            "slot_busy_frac": busy / total if total else 0.0,
            "admissions_per_segment":
                self.stats["inseg_admissions"] / segs if segs else 0.0,
            "bubble_slot_steps": float(bubble),
            "segments": float(segs),
            "prefix_hits": float(self.stats["prefix_hits"]),
            "prefix_pages_reused":
                float(self.stats["prefix_pages_reused"]),
            "cow_copies": float(self.stats["cow_copies"]),
            "evictions": float(self.stats["evictions"]),
        }

    def serve(self, reqs: Sequence[Request]) -> List[Request]:
        """Serve requests to completion: a thin closed loop over the
        open-loop core (submit all, step until done).

        Safe to interleave with open-loop use of the same engine: the loop
        stops once *these* requests are done, and completions of requests
        submitted by other callers stay queued for their
        ``drain_completions()``."""
        for r in reqs:
            self._validate(r)
        for r in reqs:
            self.submit(r)
        while self.busy and any(r.tokens is None for r in reqs):
            self.step()
        mine = {id(r) for r in reqs}
        self._completed = [r for r in self._completed
                           if id(r) not in mine]
        return list(reqs)

    # Legacy wave API (the JaxExecutor calibration path and older callers).
    def run_wave(self, reqs: Sequence[Request]) -> List[Request]:
        return self.serve(reqs)


# Explicit alias: the continuous engine is the default data plane.
ContinuousEngine = ServingEngine


class WaveEngine:
    """Seed-style run-to-completion wave engine (benchmark baseline).

    One prefill + per-token decode dispatches with a host sync every step;
    pads every wave to its longest prompt and decodes to the longest
    max_new; compiles per distinct (batch, prompt_len) shape. Kept verbatim
    (minus dead knobs) so ``benchmarks/fig_engine_throughput.py`` can
    measure the continuous engine against it.
    """

    def __init__(self, model: Model, params: Any, max_batch: int = 8,
                 pad_to: int = 32):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.pad_to = pad_to
        self.stats: Dict[str, int] = {"prefill_traces": 0,
                                      "decode_traces": 0}

        def _prefill(p, b):
            self.stats["prefill_traces"] += 1
            return model.prefill(p, b)

        def _decode(p, c, t, pos):
            self.stats["decode_traces"] += 1
            return model.decode(p, c, t, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _pad_cache(self, cache, batch: int, max_len: int):
        shapes = self.model.cache_shapes(batch, max_len, enc_len=self.pad_to)

        def pad(c, tgt):
            if c.shape == tgt.shape:
                return c.astype(tgt.dtype)
            pads = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
            return jnp.pad(c, pads).astype(tgt.dtype)
        return jax.tree.map(pad, cache, shapes)

    def run_wave(self, reqs: Sequence[Request]) -> List[Request]:
        """Serve one batch of requests to completion (greedy decoding)."""
        t0 = time.perf_counter()
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((B, plen, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
        logits, cache = self._prefill(self.params, batch)
        max_new = max(r.max_new_tokens for r in reqs)
        cache = self._pad_cache(cache, B, plen + max_new)
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok, plen + t)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)[:, None]
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            r.tokens = out[i, : r.max_new_tokens]
            r.latency = dt
        return list(reqs)

    def serve(self, reqs: Sequence[Request]) -> List[Request]:
        """Adaptive batching across waves of at most max_batch requests."""
        done: List[Request] = []
        pending = list(reqs)
        while pending:
            wave, pending = pending[: self.max_batch], \
                pending[self.max_batch:]
            done.extend(self.run_wave(wave))
        return done


class JaxExecutor:
    """Real executor for INFaaS workers: variant -> (engine, measured t(b)).

    Loads reduced-config models for the variants' architectures (host-sized)
    and measures actual wall-clock service times, which calibrate the
    simulator's profile-driven executor. ``execute`` warms the engine's
    compile caches for the request shape first, so measured service times
    are pure execution (the seed paid XLA compile time inside measurement).
    """

    def __init__(self, arch_cfgs: Dict[str, ArchConfig], seed: int = 0,
                 **engine_kwargs):
        self.engines: Dict[str, ServingEngine] = {}
        # keyed on (arch, batch, prompt_len): mixed-length calibration runs
        # are distinct measurements and must not overwrite each other
        self.measured: Dict[Tuple[str, int, int], float] = {}
        rng = jax.random.PRNGKey(seed)
        for name, cfg in arch_cfgs.items():
            model = build_model(cfg)
            params = model.init(rng)
            self.engines[name] = ServingEngine(model, params,
                                               **engine_kwargs)

    def execute(self, arch: str, batch: int, prompt_len: int = 8,
                max_new: int = 4) -> float:
        eng = self.engines[arch]
        eng.warmup(prompt_lens=[prompt_len])
        reqs = [Request(rid=i, prompt=np.arange(prompt_len) % 7,
                        max_new_tokens=max_new) for i in range(batch)]
        t0 = time.perf_counter()
        eng.serve(reqs)
        dt = time.perf_counter() - t0
        self.measured[(arch, batch, prompt_len)] = dt
        return dt
