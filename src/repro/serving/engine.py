"""Real-execution serving data plane: continuous-batching, device-resident
decode engine with shape bucketing.

This is the data plane behind a ``JaxExecutor`` worker: the INFaaS control
plane picks the variant; this engine actually runs it. The design replaces
the seed's run-to-completion waves (one device dispatch *and one host sync
per generated token*, one XLA compile per distinct ``(batch, prompt_len)``)
with three mechanisms:

**Slot scheduler (continuous batching).** The engine owns a preallocated
max-shape KV cache of ``max_batch`` slots x ``max_len`` positions plus
per-slot ``tok``/``pos``/``remaining`` arrays, all device-resident. A
request is admitted by prefilling its prompt (batch 1, right-padded to a
bucket) and inserting the resulting cache into a free slot via
``dynamic_update_slice`` along each leaf's batch axis — there is no
post-prefill ``_pad_cache`` copy of the whole batch. Slots are freed the
moment their sequence finishes and refilled from the pending queue between
decode segments, so short requests never wait for the longest request in a
wave.

**Fused decode segments.** Decoding runs as a ``lax.while_loop`` over
``model.decode`` inside one jitted function: up to ``decode_block`` tokens
for all slots are generated in a single device dispatch with a single
host sync at the end (the seed engine synced every token). Each slot
carries its own position vector (``decode``'s per-sequence ``pos``) and an
activity mask; finished slots stop advancing, and the loop exits early
when every slot is done, so drained batches stop costing FLOPs.

**Shape bucketing + warmup.** Prompt lengths are padded up to power-of-two
buckets (>= ``min_bucket``, <= ``max_len``) and admit batches are bucketed
to {1, max_batch} (same-bucket prompts admitted in one dispatch; padding
rows scatter out of bounds and are dropped), with prefill executables
keyed on the (bucket_batch, bucket_len) pair — a mixed-length request
stream compiles at most two prefills per prompt bucket and exactly one
decode-segment program per engine.
``warmup(prompt_lens=...)`` triggers those compiles eagerly so calibration
(``JaxExecutor``) and latency-sensitive serving never pay compile time
inside a measured service time. ``stats`` counts actual retraces
(``prefill_traces`` / ``decode_traces``), which tests pin down.

**Paged KV cache (block tables).** With ``page_size=None`` (default) every
slot owns a contiguous ``max_len`` run of KV positions, so slot count is
bound by worst-case context length even when most requests are short —
exactly the over-provisioning INFaaS's model-level autoscaling argues
against. With ``page_size=P`` the attention cache becomes a shared page
pool ``(L, n_pages, P, K, D)`` plus a per-slot block table
(``repro.models.kvcache``): admission is gated on *free pages* (a request
reserves ``ceil((prompt + max_new - 1) / P)`` pages, its worst case) rather
than free max-shape slots, pages are appended to a slot's block table as
its ``pos`` crosses a page boundary (topped up ahead of each decode
segment) and returned to the free list the moment the sequence finishes.
``n_pages`` defaults to ``max_batch * max_len / page_size`` (capacity
parity); provisioning fewer pages than slots-worth is the point — a
long-tail stream of mostly-short requests runs ``n_pages * P / max_len``-
slot hardware at far higher concurrency. Recurrent families' O(1) states
(SSM/conv/xLSTM) have no sequence axis and stay slot-indexed; greedy
outputs are bit-identical to the contiguous engine (the gathered view an
attention step sees is position-for-position the same tensor).

**Chunked prefill.** A long prompt's monolithic prefill dispatch used to
stall every in-flight decode for the whole prompt length. With
``chunk_threshold=T`` set, prompts longer than ``T`` skip the prefill
dispatch entirely: the prompt is staged in a device-resident per-slot
prompt buffer and *teacher-forced through the fused decode segment* —
each segment consumes up to ``decode_block`` prompt tokens for that slot
(writing KV, discarding logits until the prompt is exhausted, then
switching to greedy emission) while other slots keep generating in the
same dispatch. A near-``max_len`` prompt admitted mid-stream therefore
delays in-flight decodes by zero extra dispatches. Chunked admission is
enabled for the dense/hybrid/ssm families — each slot restarts from the
family's empty decode state via ``Model.empty_state`` (all-zeros, except
xLSTM's -inf stabilizers). Audio/vlm need encoder KV from prefill, and
MoE's expert-capacity keep/drop decisions depend on the co-batched token
set (prompt tokens fed inside the shared decode batch would diverge from
the solo prefill the engine guarantees), so those families admit whole
prompts regardless of the knob.

**In-segment admission (staging ring).** Even with chunked prefill, a slot
that finishes mid-segment idles until the ``lax.while_loop`` exits, and a
newly arrived request waits for the next ``step()`` boundary — the
occupancy bubble that inflates tail latency under bursty short-request
load. With ``stage_slots=N`` the engine keeps a device-resident staging
ring of up to ``N`` pending requests (prompt rows, lengths, ``max_new``,
and — in paged mode — pre-reserved block-table rows): the decode loop's
carry tracks a ring head, and the moment a slot's ``rem`` hits zero
mid-segment the loop records the completion in a per-slot completion log
and pulls the next staged request into the freed slot — resetting
``pos``/``rem``/``plen``/prompt-buffer pointers, restoring the slot's O(1)
recurrent-state rows to the family's empty state
(``Model.empty_state`` — xLSTM's stabilizers start at -inf, not zero),
and switching the slot to the staged request's block-table row. One
dispatch can therefore retire *multiple* requests per slot with zero
extra dispatches or host syncs; the host decodes the completion log after
the segment to split each slot's emission row between its successive
occupants. Staged requests teacher-force their prompts through the fused
segment exactly like chunked prefill, so in-segment admission is gated to
the same families whose teacher-forced decode is exact from the empty
state (dense/hybrid/ssm); other families clamp ``stage_slots`` to 0 and
keep boundary-only admission. In paged mode a staged request holds its
worst-case page reservation from staging time (its first
``decode_block`` positions' pages are materialized up front, since no
host boundary can top it up mid-segment); ``PageAllocator`` tracks these
staged reservations under per-request tickets that are re-keyed to the
slot at harvest.

**Optimistic admission + SLO-aware preemption.** Worst-case admission
(``admission="worstcase"``, the default) reserves every request's full
``ceil((prompt + max_new - 1) / P)`` pages up front, so the pool is
chronically under-committed: the decode tail is reserved long before it is
written, and the only failure mode under pressure is head-of-line
queueing. ``admission="optimistic"`` admits on *expected* usage instead —
a prefill request needs its prompt pages now (they are scattered at the
prefill dispatch) and a chunked request only its first ``decode_block``
stride — and grows the decode tail lazily. When the pool runs dry at a
growth point (a live slot's ``pos`` is about to cross a page boundary
with zero free pages — at the segment-boundary top-up, or because staged
in-segment refills hold pages), the engine *preempts* instead of wedging:
staged-but-unstarted requests are un-staged first (zero work lost), then
a live victim is chosen, its pages freed, and the request parked host-side
with its prompt plus every token generated so far. Re-admission
teacher-forces that full prefix through the chunked-prefill path, so
recovery is **bit-identical** to an uninterrupted run (greedy decode is
deterministic given the prefix). Victim choice is SLO-aware
(``preempt_policy="slack"``): each ``Request`` carries its latency
objective (``slo``), and the engine preempts the request with the most
slack — deadline minus elapsed minus estimated remaining (segment-time
EWMA x positions left) — treating no-SLO requests as infinite slack and
breaking ties toward longest-remaining; ``preempt_policy="lru"`` preempts
the most recently admitted request instead (vLLM-style recompute).
Optimistic admission requires the paged layout and a family whose
teacher-forced decode is exact from the empty state (dense/hybrid/ssm);
other configurations clamp back to worst-case. ``stats`` counts
``preemptions``, ``preempt_readmits`` and ``pressure_stalls`` (growth
points that found the pool dry), and each ``Request`` counts its own
``preemptions`` so callers can surface a ``degraded`` flag.

**Occupancy accounting.** ``stats`` tracks ``busy_slot_steps`` /
``bubble_slot_steps`` (active vs idle slot-steps inside fused segments,
counted in the loop carry), ``inseg_admissions`` and ``staged``; the
``occupancy`` property derives the per-segment slot-busy fraction and
admissions-per-segment that ``EngineExecutor`` threads into its
decision log.

**Open-loop core.** The engine is step-driven: state (slot occupancy,
pending queue, per-slot generations) persists on the engine, and the three
phases of the serving loop are separately callable —

* ``submit(req)``     enqueue a request (at any time, including while other
  requests are mid-decode); its latency clock starts at ``Request.arrival``
  (stamped at submit if unset),
* ``step()``          admit pending requests into free slots, run ONE fused
  decode segment, harvest finished slots,
* ``drain_completions()``  collect requests finished since the last drain.

Mid-stream admission falls out: a request submitted between segments joins
the next ``step()`` without restarting in-flight slots. ``serve()`` is a
thin closed loop over the core (submit all, step until idle) and produces
bit-identical outputs and identical trace/dispatch counts to the closed
PR-1 loop. The open seam is what lets the INFaaS control plane
(``EngineExecutor`` in ``repro.serving.executor``) drive real engines.

Exactness: for the dense/hybrid/ssm (and, by the same causal-masking
argument, vlm) families the engine emits token-for-token the same greedy
outputs as a serial per-request prefill+decode (prompts are right-padded;
causal attention masks padded KV via per-sequence valid lengths, and
recurrent families mask their state updates — see ``repro.models.model``).
MoE matches serial decode except when GShard-style expert capacity —
a static function of the padded token count — crosses a boundary between
the prompt's bucket and its exact length and flips a token-drop decision
(see ``prefill_moe``); MoE prompts are therefore admitted one per
dispatch, which keeps decode exact and confines the effect to prefill.
The audio family masks its encoder self-attention and decoder
cross-attention by each request's true encoder length (threaded through
the cache as a per-slot ``enc_len``), so padded encoder rows contribute
exact zeros: audio outputs are padding-independent, and the paged layout
(whose dropped writes leave padding rows stale) is bit-identical to
contiguous for audio too.

The seed wave engine survives as ``WaveEngine`` — the benchmark baseline
for ``benchmarks/fig_engine_throughput.py``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import kvcache as KV
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 8
    arrival: float = 0.0
    tokens: Optional[np.ndarray] = None
    latency: float = 0.0
    # wall time the request entered a device slot (prefill, chunked, or
    # in-segment promotion at harvest); admitted - arrival is queue delay
    admitted: float = -1.0
    # per-query latency objective in seconds (deadline = arrival + slo);
    # None = best-effort. Drives SLO-aware victim choice under pressure.
    slo: Optional[float] = None
    # times this request was preempted (pages freed, parked, prefix
    # replayed); > 0 lets callers surface a "degraded" flag on results
    preemptions: int = 0


@dataclasses.dataclass
class _Parked:
    """A preempted request parked host-side awaiting re-admission."""
    req: Request
    prefix: np.ndarray      # prompt + every token generated before preempt
    done: List[int]         # tokens already generated (re-credited at seat)


def bucket_len(n: int, minimum: int = 8, maximum: Optional[int] = None) -> int:
    """Round ``n`` up to a power of two >= ``minimum`` (clamped to maximum)."""
    b = max(minimum, 1 << max(int(n) - 1, 0).bit_length())
    if maximum is not None:
        if n > maximum:
            raise ValueError(f"length {n} exceeds engine max_len {maximum}")
        b = min(b, maximum)
    return b


class PageAllocator:
    """Host-side accounting for the shared KV page pool.

    Admission reserves a holder's worst case (``ceil(n_positions /
    page_size)`` pages for ``prompt_len + max_new - 1`` written positions)
    so a decode can never strand mid-stream for lack of pages — ``cover()``
    calls, which lazily hand out physical pages as ``pos`` grows, always
    succeed within the reservation. Holders are arbitrary hashable keys:
    the engine keys live slots by slot index and staged-but-unadmitted
    requests (in-segment admission) by per-request tickets, re-keyed to
    the slot via ``rekey()`` when the staging ring promotes them.
    Invariants (pinned by the hypothesis property test): no page is ever
    held by two live holders, ``free + staged + live == n_pages`` at all
    times, and a full drain returns every page to the free list.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError(f"bad pool: {n_pages} pages x {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages))[::-1]
        self._pages: Dict[Any, List[int]] = {}     # holder -> held page ids
        self._reserved: Dict[Any, int] = {}        # holder -> worst case

    def pages_needed(self, n_positions: int) -> int:
        return max(0, -(-int(n_positions) // self.page_size))

    @property
    def committed(self) -> int:
        """Pages promised to live slots (held now or claimable later)."""
        return sum(self._reserved.values())

    @property
    def n_free(self) -> int:
        return len(self._free)

    def live_pages(self) -> List[int]:
        return [p for pages in self._pages.values() for p in pages]

    def pages_of(self, slot: int) -> List[int]:
        return list(self._pages.get(slot, ()))

    def can_reserve(self, n_positions: int) -> bool:
        return self.committed + self.pages_needed(n_positions) <= self.n_pages

    def reserve(self, slot: Any, n_positions: int,
                strict: bool = True) -> None:
        """Admit ``slot``: commit its worst-case page count (no pages yet).

        ``strict=False`` (optimistic admission) skips the over-commit
        check: the engine admits on expected usage, lets ``committed``
        exceed the pool, and resolves a dry pool by preemption instead of
        up-front refusal."""
        if slot in self._reserved:
            raise ValueError(f"slot {slot} already live")
        need = self.pages_needed(n_positions)
        if strict and self.committed + need > self.n_pages:
            raise ValueError(f"over-committed: {self.committed}+{need} "
                             f"> {self.n_pages}")
        self._reserved[slot] = need
        self._pages[slot] = []

    def can_cover(self, holder: Any, n_positions: int) -> bool:
        """Enough free pages for ``cover(holder, n_positions)``? Always
        true under worst-case admission (the reservation pre-funds every
        cover); optimistic admission uses this as its pressure probe."""
        held = len(self._pages[holder])
        target = min(self.pages_needed(n_positions),
                     self._reserved[holder])
        return target - held <= len(self._free)

    def cover(self, slot: int, n_positions: int) -> List[int]:
        """Grow ``slot`` to cover positions [0, n); returns the new pages."""
        held = self._pages[slot]
        target = min(self.pages_needed(n_positions), self._reserved[slot])
        grown = []
        while len(held) < target:
            page = self._free.pop()
            grown.append(page)
            held.append(page)
        return grown

    def release(self, slot: int) -> List[int]:
        """Free all of ``slot``'s pages (sequence finished)."""
        pages = self._pages.pop(slot)
        del self._reserved[slot]
        self._free.extend(pages)
        return pages

    def rekey(self, old: Any, new: Any) -> None:
        """Transfer a reservation (and its held pages) to a new holder key:
        a staged request's ticket becomes the slot it was pulled into."""
        if new in self._reserved:
            raise ValueError(f"holder {new!r} already live")
        self._reserved[new] = self._reserved.pop(old)
        self._pages[new] = self._pages.pop(old)


class ServingEngine:
    """Continuous-batching engine over one model + params (greedy decode)."""

    def __init__(self, model: Model, params: Any, max_batch: int = 8,
                 max_len: int = 128, decode_block: int = 16,
                 min_bucket: int = 8, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 chunk_threshold: Optional[int] = None,
                 stage_slots: int = 0, admission: str = "worstcase",
                 preempt_policy: str = "slack"):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.decode_block = decode_block
        self.min_bucket = min_bucket
        # MoE expert capacity is a function of the co-batched token count,
        # so grouped admission could change token-drop decisions vs a
        # serial run; admit MoE prompts one per dispatch to stay exact.
        self._group_admit = model.cfg.family != "moe"
        # Chunked prefill (and in-segment admission, which reuses the same
        # teacher-forcing path) restarts a slot from the family's empty
        # decode state (``Model.empty_state`` — all-zeros except xLSTM's
        # -inf stabilizers). Families whose prefill computes encoder KV
        # (audio/vlm) admit whole prompts. MoE is excluded too: its
        # expert-capacity keep/drop decisions depend on the co-batched
        # token set, so feeding prompt tokens inside the shared decode
        # batch would diverge from the solo prefill the engine otherwise
        # guarantees (see _group_admit).
        self._chunk_ok = model.cfg.family in ("dense", "hybrid", "ssm")
        self.chunk_threshold = \
            chunk_threshold if self._chunk_ok else None
        # in-segment admission: capacity of the device staging ring
        # (0 = boundary-only admission); clamped off with chunking since
        # staged prompts teacher-force through the decode segment
        self.stage_slots = int(stage_slots) if self._chunk_ok and \
            stage_slots else 0
        self.stats: Dict[str, int] = {
            "prefill_traces": 0, "decode_traces": 0, "chunk_traces": 0,
            "prefill_dispatches": 0, "decode_dispatches": 0,
            "decode_steps": 0, "tokens_generated": 0, "admitted": 0,
            "chunk_admits": 0, "peak_concurrency": 0,
            "staged": 0, "inseg_admissions": 0,
            "busy_slot_steps": 0, "bubble_slot_steps": 0,
            "preemptions": 0, "preempt_readmits": 0, "pressure_stalls": 0,
        }
        shapes = model.cache_shapes(max_batch, max_len, enc_len=max_len)
        # Per-leaf batch axis, found by diffing cache shapes at two batch
        # sizes (family-agnostic: attention caches, SSM/conv states, and
        # grouped VLM layouts all place batch differently); per-leaf
        # sequence axis likewise by diffing two max_lens (-1 for the O(1)
        # recurrent states, which have none and are never paged).
        s2 = model.cache_shapes(2, max_len, enc_len=max_len)
        s3 = model.cache_shapes(3, max_len, enc_len=max_len)
        self._batch_axes = jax.tree.map(
            lambda a, b: next(i for i, (x, y) in
                              enumerate(zip(a.shape, b.shape)) if x != y),
            s2, s3)
        l2 = model.cache_shapes(2, max_len + 8, enc_len=max_len + 8)
        self._seq_axes = jax.tree.map(
            lambda a, b: next((i for i, (x, y) in
                               enumerate(zip(a.shape, b.shape)) if x != y),
                              -1),
            s2, l2)
        # ----- paged layout -------------------------------------------
        self.page_size = page_size
        if page_size is not None:
            if max_len % page_size != 0:
                raise ValueError(f"max_len {max_len} not a multiple of "
                                 f"page_size {page_size}")
            self.pages_per_slot = max_len // page_size
            self.n_pages = (max_batch * self.pages_per_slot
                            if n_pages is None else n_pages)
            pageable = any(s != -1 for s in jax.tree.leaves(self._seq_axes))
        else:
            pageable = False
        if pageable:
            self._alloc: Optional[PageAllocator] = \
                PageAllocator(self.n_pages, page_size)
            # block-table mirror handed to every device dispatch; the
            # sentinel n_pages drops writes / clamps (masked) reads
            self._bt = KV.sentinel_block_table(
                max_batch, self.pages_per_slot, self.n_pages)
            self._cache = jax.tree.map(
                lambda s, bax, sax: jnp.zeros(
                    self._pool_shape(s.shape, bax, sax), s.dtype),
                shapes, self._batch_axes, self._seq_axes)
        else:
            # contiguous layout — also the path for attention-free
            # families (pure-recurrent xLSTM), whose O(1) states have
            # nothing to page regardless of the knob
            if page_size is None:
                self.pages_per_slot = 0
                self.n_pages = 0
            self._alloc = None
            self._bt = None
            self._cache = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        self._paged = self._bt is not None
        # ----- admission discipline -----------------------------------
        if admission not in ("worstcase", "optimistic"):
            raise ValueError(f"unknown admission mode {admission!r}")
        if preempt_policy not in ("slack", "lru"):
            raise ValueError(f"unknown preempt policy {preempt_policy!r}")
        # Optimistic admission needs (a) the paged layout — pressure is a
        # page-pool phenomenon — and (b) a family whose teacher-forced
        # decode is exact from the empty state, because recovery replays
        # the preempted prefix through the chunked-prefill seat. Anything
        # else clamps back to worst-case (forced ``preempt()`` still works
        # for any chunk-capable family).
        self.admission = admission if (self._alloc is not None and
                                       self._chunk_ok) else "worstcase"
        self.preempt_policy = preempt_policy
        # Per-leaf empty-state rows (batch axis moved to front, batch=1):
        # the slot-reset constant for chunked admission and the fused
        # loop's in-segment refill. Sequence-carrying leaves never need a
        # reset (their positions are rewritten before any masked read), so
        # they get a dummy scalar the reset paths skip by seq axis.
        if model.empty_state is not None:
            empty1 = model.empty_state(1, max_len, enc_len=max_len)
        else:
            s1 = model.cache_shapes(1, max_len, enc_len=max_len)
            empty1 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), s1)
        self._reset_rows = jax.tree.map(
            lambda e, bax, sax: (jnp.moveaxis(jnp.asarray(e), bax, 0)
                                 if sax == -1 else jnp.zeros((), e.dtype)),
            empty1, self._batch_axes, self._seq_axes)
        self._tok = jnp.zeros((max_batch, 1), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        self._rem = jnp.zeros((max_batch,), jnp.int32)
        # chunked-prefill staging: per-slot prompt buffer + prompt length
        # (0 = slot admitted via prefill, nothing left to feed)
        self._plen = jnp.zeros((max_batch,), jnp.int32)
        self._pbuf = jnp.zeros((max_batch, max_len), jnp.int32)
        self._prefill_fns: Dict[int, Any] = {}
        self._decode_fn = None
        self._chunk_fn = None
        # open-loop state: persists across submit()/step() calls so
        # requests can arrive while earlier ones are mid-decode
        self._pending: deque = deque()
        self._slot_req: List[Optional[Request]] = [None] * max_batch
        self._gen: Dict[int, List[int]] = {}
        self._free: List[int] = list(range(max_batch))[::-1]
        self._slot_pos = np.zeros((max_batch,), np.int64)
        self._completed: List[Request] = []
        # staging ring (in-segment admission): FIFO of
        # (request, allocator ticket, block-table row) awaiting a freed
        # slot inside a fused segment; mirrors the device ring each step
        self._staged: deque = deque()
        self._stage_seq = 0
        # preempted requests parked host-side (``_Parked``), FIFO; they
        # re-admit ahead of pending work via the chunked-prefill seat
        self._preempted: deque = deque()
        # EWMA of per-decode-step wall time: the slack policy's estimate
        # of a request's remaining service time (positions left x this)
        self._step_est = 0.0

    def _pool_shape(self, dims: Tuple[int, ...], bax: int, sax: int):
        """Contiguous leaf shape -> shared-pool shape: drop the batch axis,
        split the sequence axis into (n_pages, page_size). State leaves
        (sax == -1) keep their slot-indexed shape."""
        if sax == -1:
            return dims
        assert bax < sax, (dims, bax, sax)
        return (dims[:bax] + dims[bax + 1:sax]
                + (self.n_pages, self.page_size) + dims[sax + 1:])

    def _n_positions(self, r: Request) -> int:
        """KV positions a request writes over its lifetime: the prompt plus
        one per generated token except the last (never fed back)."""
        return len(r.prompt) + max(r.max_new_tokens, 1) - 1

    # ------------------------------------------------------------------
    # compiled programs (keyed on (bucket_batch, bucket_len) shape)
    def _get_prefill(self, bucket: int, nbatch: int):
        key = (nbatch, bucket)
        fn = self._prefill_fns.get(key)
        if fn is not None:
            return fn
        model, cfg = self.model, self.model.cfg
        baxes, saxes = self._batch_axes, self._seq_axes
        paged, ps = self._paged, self.page_size

        def prefill_admit(params, cache, tok, pos, rem, plen, tokens,
                          lengths, slots, max_news, page_rows=None):
            # tokens: (nbatch, bucket); lengths/slots/max_news: (nbatch,).
            # Padding rows carry slot == max_batch: out-of-bounds scatter
            # indices are dropped, so they touch no live slot. In paged
            # mode page_rows (nbatch, ceil(bucket/ps)) routes each leaf's
            # cache slice into the slot's pages (sentinel rows drop —
            # bucket padding past the allocated pages never lands).
            self.stats["prefill_traces"] += 1   # Python side effect: runs
            batch = {"tokens": tokens,          # once per (re)trace only
                     "length": lengths}
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros((nbatch, bucket, cfg.d_model),
                                            cfg.dtype)
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (nbatch, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
            logits, pcache = model.prefill(params, batch)
            firsts = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

            def insert(slot_leaf, new_leaf, bax):
                pads = [(0, 0) if i == bax else (0, t - s)
                        for i, (s, t) in enumerate(zip(new_leaf.shape,
                                                       slot_leaf.shape))]
                new_leaf = jnp.pad(new_leaf, pads).astype(slot_leaf.dtype)
                arr = jnp.moveaxis(slot_leaf, bax, 0)
                rows = jnp.moveaxis(new_leaf, bax, 0)
                arr = arr.at[slots].set(rows, mode="drop")
                return jnp.moveaxis(arr, 0, bax)

            def insert_paged(pool_leaf, new_leaf, bax, sax):
                # page-shape the slice: split its sequence axis into
                # (n_pages_of_bucket, page_size) rows, then scatter each
                # row to its block-table page (shared pool, batch-free)
                if sax == -1:
                    return insert(pool_leaf, new_leaf, bax)
                n_rows = page_rows.shape[1]
                new = jnp.moveaxis(new_leaf, bax, 0)    # (nb, .., S@sax, ..)
                padspec = [(0, 0)] * new.ndim
                padspec[sax] = (0, n_rows * ps - new.shape[sax])
                new = jnp.pad(new, padspec)
                new = new.reshape(new.shape[:sax] + (n_rows, ps)
                                  + new.shape[sax + 1:])
                new = jnp.moveaxis(new, sax, 1)         # (nb, P_b, .., ps, ..)
                new = new.reshape((nbatch * n_rows,) + new.shape[2:])
                pool = jnp.moveaxis(pool_leaf, sax - 1, 0)
                pool = pool.at[page_rows.reshape(-1)].set(
                    new.astype(pool.dtype), mode="drop")
                return jnp.moveaxis(pool, 0, sax - 1)

            if paged:
                cache = jax.tree.map(insert_paged, cache, pcache,
                                     baxes, saxes)
            else:
                cache = jax.tree.map(insert, cache, pcache, baxes)
            tok = tok.at[slots].set(firsts[:, None], mode="drop")
            pos = pos.at[slots].set(lengths, mode="drop")
            rem = rem.at[slots].set(max_news - 1, mode="drop")
            plen = plen.at[slots].set(jnp.zeros_like(max_news), mode="drop")
            return cache, tok, pos, rem, plen, firsts

        fn = jax.jit(prefill_admit)
        self._prefill_fns[key] = fn
        return fn

    def _get_chunk_admit(self):
        """Compiled chunked admission: stage the full prompt in the slot's
        device prompt buffer (no prefill dispatch) and reset the slot's
        recurrent state rows; the decode segment teacher-forces the prompt
        from there, ``decode_block`` tokens per segment."""
        if self._chunk_fn is not None:
            return self._chunk_fn
        baxes, saxes = self._batch_axes, self._seq_axes
        reset_rows = self._reset_rows

        n_slots = self.max_batch

        def chunk_admit(cache, tok, pos, rem, plen, pbuf, slot, row,
                        plen_v, max_new):
            # slot/plen_v/max_new: (1,); row: (1, max_len)
            self.stats["chunk_traces"] += 1
            # KV leaves need no reset: a position is always rewritten by
            # this slot before any masked read can include it. O(1) state
            # leaves carry the previous occupant's final state and must
            # restart from the family's empty state (zeros, except e.g.
            # xLSTM's -inf stabilizers) — same primitive the fused loop's
            # in-segment refill uses, with a one-hot slot mask.
            take = jnp.arange(n_slots) == slot[0]
            cache = jax.tree.map(
                lambda leaf, bax, sax, empty_row:
                    leaf if sax != -1
                    else KV.reset_slot_rows(leaf, bax, take, empty_row),
                cache, baxes, saxes, reset_rows)
            tok = tok.at[slot].set(row[:, :1])
            pos = pos.at[slot].set(jnp.zeros((1,), jnp.int32))
            rem = rem.at[slot].set(max_new)
            plen = plen.at[slot].set(plen_v)
            pbuf = pbuf.at[slot].set(row)
            return cache, tok, pos, rem, plen, pbuf

        self._chunk_fn = jax.jit(chunk_admit)
        return self._chunk_fn

    def _get_decode(self):
        if self._decode_fn is not None:
            return self._decode_fn
        model, steps, slots = self.model, self.decode_block, self.max_batch
        paged, max_len = self._paged, self.max_len
        R = max(self.stage_slots, 1)      # device ring capacity (static)
        max_comps = slots + R             # completion-log capacity
        baxes, saxes = self._batch_axes, self._seq_axes
        reset_rows = self._reset_rows

        def decode_segment(params, cache, tok, pos, rem, plen, pbuf,
                           ring_tok, ring_plen, ring_new, n_stage,
                           bt=None, ring_bt=None):
            # ring_tok: (R, max_len) staged prompt rows; ring_plen /
            # ring_new: (R,) prompt lengths and max_new budgets; n_stage:
            # scalar count of valid ring entries (0 disables refill);
            # ring_bt: (R, pages_per_slot) pre-reserved block-table rows.
            self.stats["decode_traces"] += 1
            slot_ids = jnp.arange(slots, dtype=jnp.int32)

            def cond(st):
                return (st["i"] < steps) & jnp.any(st["rem"] > 0)

            def body(st):
                i, cache = st["i"], st["cache"]
                tok, pos, rem = st["tok"], st["pos"], st["rem"]
                plen, pbuf = st["plen"], st["pbuf"]
                bt_c = st.get("bt")
                active = rem > 0
                dcache = dict(cache, bt=bt_c) if paged else cache
                logits, dcache = model.decode(params, dcache, tok, pos)
                if paged:
                    dcache = {k: v for k, v in dcache.items() if k != "bt"}
                cache = dcache
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                # chunked prefill: while prompt tokens remain, feed the
                # next one instead of the sampled token and emit nothing
                feeding = (pos + 1) < plen
                pnext = jnp.take_along_axis(
                    pbuf, jnp.clip(pos + 1, 0, max_len - 1)[:, None],
                    axis=1)[:, 0]
                nxt = jnp.where(feeding, pnext, nxt)
                emit = jnp.where(active & ~feeding, nxt, -1)
                out = lax.dynamic_update_slice(st["out"], emit[:, None],
                                               (0, i))
                tok = jnp.where(active[:, None], nxt[:, None], tok)
                pos = jnp.where(active, pos + 1, pos)
                rem = jnp.where(active & ~feeding, rem - 1, rem)
                # ---- completion log + in-segment slot refill ----------
                # Freshly finished slots are logged (slot, step) in slot
                # order; the first `avail` of them pull the next staged
                # requests (FIFO: j-th admitted completion of the segment
                # takes ring entry j), resetting the slot inside the loop
                # so the dispatch retires multiple requests per slot.
                fin = active & ~feeding & (rem == 0)
                nfin = jnp.sum(fin.astype(jnp.int32))
                head = st["head"]
                avail = n_stage - head
                rank = jnp.cumsum(fin.astype(jnp.int32)) - 1
                adm = fin & (rank < avail)
                src = jnp.clip(head + rank, 0, R - 1)
                log_idx = jnp.where(fin, st["n_comp"] + rank, max_comps)
                comp_slot = st["comp_slot"].at[log_idx].set(
                    slot_ids, mode="drop")
                comp_step = st["comp_step"].at[log_idx].set(i, mode="drop")
                comp_adm = st["comp_adm"].at[log_idx].set(
                    adm.astype(jnp.int32), mode="drop")
                rows = jnp.take(ring_tok, src, axis=0)     # (B, max_len)
                tok = jnp.where(adm[:, None], rows[:, :1], tok)
                pbuf = jnp.where(adm[:, None], rows, pbuf)
                pos = jnp.where(adm, 0, pos)
                rem = jnp.where(adm, jnp.take(ring_new, src), rem)
                plen = jnp.where(adm, jnp.take(ring_plen, src), plen)
                cache = jax.tree.map(
                    lambda leaf, bax, sax, row:
                        leaf if sax != -1
                        else KV.reset_slot_rows(leaf, bax, adm, row),
                    cache, baxes, saxes, reset_rows)
                new = dict(
                    i=i + 1, cache=cache, tok=tok, pos=pos, rem=rem,
                    plen=plen, pbuf=pbuf, out=out,
                    head=head + jnp.minimum(nfin, jnp.maximum(avail, 0)),
                    comp_slot=comp_slot, comp_step=comp_step,
                    comp_adm=comp_adm, n_comp=st["n_comp"] + nfin,
                    busy=st["busy"] + jnp.sum(active.astype(jnp.int32)))
                if paged:
                    new["bt"] = jnp.where(adm[:, None],
                                          jnp.take(ring_bt, src, axis=0),
                                          bt_c)
                return new

            st0 = dict(i=jnp.int32(0), cache=cache, tok=tok, pos=pos,
                       rem=rem, plen=plen, pbuf=pbuf,
                       out=jnp.full((slots, steps), -1, jnp.int32),
                       head=jnp.int32(0),
                       comp_slot=jnp.zeros((max_comps,), jnp.int32),
                       comp_step=jnp.zeros((max_comps,), jnp.int32),
                       comp_adm=jnp.zeros((max_comps,), jnp.int32),
                       n_comp=jnp.int32(0), busy=jnp.int32(0))
            if paged:
                st0["bt"] = jnp.asarray(bt)
            st = lax.while_loop(cond, body, st0)
            return (st["cache"], st["tok"], st["pos"], st["rem"],
                    st["plen"], st["pbuf"], st["out"], st["comp_slot"],
                    st["comp_step"], st["comp_adm"], st["n_comp"],
                    st["busy"], st["i"])

        if paged:
            self._decode_fn = jax.jit(decode_segment)
        else:
            self._decode_fn = jax.jit(
                lambda params, cache, tok, pos, rem, plen, pbuf,
                rtok, rplen, rnew, n_stage:
                decode_segment(params, cache, tok, pos, rem, plen, pbuf,
                               rtok, rplen, rnew, n_stage))
        return self._decode_fn

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens: Sequence[int] = (),
               include_decode: bool = True) -> None:
        """Compile prefill executables for the (batch, length) buckets
        covering ``prompt_lens`` (plus the minimum bucket) and the decode
        segment.

        Warmup calls run against the live state with every scatter index
        out of bounds (dropped), so engine state is untouched; subsequent
        serving on these buckets never recompiles.
        """
        lens = [n for n in prompt_lens
                if self.chunk_threshold is None or n <= self.chunk_threshold]
        buckets = {bucket_len(max(n, 1), self.min_bucket, self.max_len)
                   for n in lens + [1]}       # chunked lens never prefill
        nbatches = {1, self.max_batch} if self._group_admit else {1}
        for b in sorted(buckets):
            for nb in sorted(nbatches):
                if (nb, b) in self._prefill_fns:
                    continue        # already compiled; skip the dummy run
                fn = self._get_prefill(b, nb)
                args = [self.params, self._cache, self._tok, self._pos,
                        self._rem, self._plen, np.zeros((nb, b), np.int32),
                        np.ones((nb,), np.int32),
                        np.full((nb,), self.max_batch, np.int32),
                        np.ones((nb,), np.int32)]
                if self._paged:
                    args.append(np.full((nb, self._page_rows_for(b)),
                                        self.n_pages, np.int32))
                out = fn(*args)
                jax.block_until_ready(out[-1])
        if include_decode and self._decode_fn is None:
            fn = self._get_decode()
            R = max(self.stage_slots, 1)
            args = [self.params, self._cache, self._tok, self._pos,
                    jnp.zeros((self.max_batch,), jnp.int32), self._plen,
                    self._pbuf, np.zeros((R, self.max_len), np.int32),
                    np.zeros((R,), np.int32), np.zeros((R,), np.int32),
                    np.int32(0)]
            if self._paged:
                args += [self._bt, KV.sentinel_block_table(
                    R, self.pages_per_slot, self.n_pages)]
            out = fn(*args)
            jax.block_until_ready(out[-1])
        if (self.chunk_threshold is not None
                or self.admission == "optimistic") and \
                self._chunk_fn is None:
            # optimistic engines seat preempted prefixes through the chunk
            # path even with chunking off: compile it out of band too
            fn = self._get_chunk_admit()
            out = fn(self._cache, self._tok, self._pos, self._rem,
                     self._plen, self._pbuf,
                     np.full((1,), self.max_batch, np.int32),
                     np.zeros((1, self.max_len), np.int32),
                     np.zeros((1,), np.int32), np.zeros((1,), np.int32))
            jax.block_until_ready(out[1])

    def _page_rows_for(self, bucket: int) -> int:
        """Block-table rows a bucket-wide prefill slice spans."""
        return -(-bucket // self.page_size)

    # ------------------------------------------------------------------
    def _admit_group(self, bucket: int, rs: List[Request],
                     slots: List[int]) -> np.ndarray:
        """One prefill dispatch admitting same-bucket requests into slots.

        Admit batches are bucketed to {1, max_batch} so the executable
        count stays at <= 2 per prompt bucket; padding rows point their
        scatter index past the last slot and are dropped. In paged mode
        each request's prompt pages are allocated here (its block-table
        row was reserved at pop time) and the prefill scatters page-shaped
        cache slices through them.
        """
        m = len(rs)
        nb = 1 if m == 1 else self.max_batch
        tokens = np.zeros((nb, bucket), np.int32)
        lengths = np.ones((nb,), np.int32)
        slot_idx = np.full((nb,), self.max_batch, np.int32)
        max_news = np.ones((nb,), np.int32)
        for j, (r, s) in enumerate(zip(rs, slots)):
            tokens[j, : len(r.prompt)] = r.prompt       # right-pad
            lengths[j] = len(r.prompt)
            slot_idx[j] = s
            max_news[j] = max(r.max_new_tokens, 1)
        fn = self._get_prefill(bucket, nb)
        args = [self.params, self._cache, self._tok, self._pos, self._rem,
                self._plen, tokens, lengths, slot_idx, max_news]
        if self._paged:
            n_rows = self._page_rows_for(bucket)
            page_rows = np.full((nb, n_rows), self.n_pages, np.int32)
            for j, (r, s) in enumerate(zip(rs, slots)):
                self._grow_slot(s, len(r.prompt))
                page_rows[j] = self._bt[s, :n_rows]
            args.append(page_rows)
        (self._cache, self._tok, self._pos, self._rem, self._plen,
         firsts) = fn(*args)
        self.stats["prefill_dispatches"] += 1
        self.stats["admitted"] += m
        return np.asarray(firsts)[:m]

    def _grow_slot(self, slot: int, n_positions: int) -> None:
        """Extend ``slot``'s block table to cover positions [0, n)."""
        held = len(self._alloc.pages_of(slot))
        new = self._alloc.cover(slot, n_positions)
        if new:
            self._bt[slot, held:held + len(new)] = new

    def _seat_prefix(self, slot: int, prefix: np.ndarray,
                     max_new: int) -> None:
        """Seat a token prefix in ``slot`` for teacher-forced replay: the
        prefix goes to the slot's device prompt buffer, the slot's state
        rows reset to the family's empty state, and the next segments feed
        it ``decode_block`` tokens per dispatch before emitting ``max_new``
        greedy tokens. The primitive under chunked admission (prefix ==
        prompt) and preemption recovery (prefix == prompt + tokens already
        generated, which makes the continuation bit-identical)."""
        plen = len(prefix)
        row = np.zeros((1, self.max_len), np.int32)
        row[0, :plen] = prefix
        fn = self._get_chunk_admit()
        (self._cache, self._tok, self._pos, self._rem, self._plen,
         self._pbuf) = fn(
            self._cache, self._tok, self._pos, self._rem, self._plen,
            self._pbuf, np.asarray([slot], np.int32), row,
            np.asarray([plen], np.int32),
            np.asarray([max(max_new, 1)], np.int32))

    def _chunk_seat(self, r: Request, slot: int) -> None:
        """Stage ``r``'s prompt in ``slot``'s device prompt buffer and
        reset the slot's state rows (no prefill dispatch): shared by
        chunked admission and the boundary fallback that seats staged
        requests into freed slots."""
        self._seat_prefix(slot, np.asarray(r.prompt, np.int32),
                          max(r.max_new_tokens, 1))

    def _admit_chunk(self, r: Request, slot: int) -> None:
        """Chunked admission: no prefill dispatch — stage the prompt in
        the slot's device prompt buffer; the next decode segments feed it
        ``decode_block`` tokens at a time."""
        self._chunk_seat(r, slot)
        self.stats["chunk_admits"] += 1
        self.stats["admitted"] += 1

    # ------------------------------------------------------------------
    # open-loop core: submit / step / drain_completions
    @property
    def busy(self) -> bool:
        """True while any request is pending admission, staged for
        in-segment admission, parked after a preemption, or mid-decode."""
        return bool(self._pending) or bool(self._staged) or \
            bool(self._preempted) or \
            any(r is not None for r in self._slot_req)

    def _validate(self, r: Request) -> None:
        if len(r.prompt) + r.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {r.rid}: prompt_len {len(r.prompt)} + max_new "
                f"{r.max_new_tokens} exceeds engine max_len "
                f"{self.max_len}")
        if self._alloc is not None:
            need = self._alloc.pages_needed(self._n_positions(r))
            if need > self.n_pages:
                raise ValueError(
                    f"request {r.rid}: needs {need} pages but the pool "
                    f"holds {self.n_pages}; it could never be admitted")

    def submit(self, r: Request) -> None:
        """Enqueue a request; may be called at any time, including while
        other requests are mid-decode (it joins at the next ``step()``).
        The latency clock starts at ``r.arrival`` (stamped now if unset)."""
        self._validate(r)
        if r.arrival == 0.0:
            r.arrival = time.perf_counter()
        self._pending.append(r)

    def _admit_pending(self) -> None:
        """Fill free slots from the pending queue (grouped by bucket),
        then top up the staging ring for in-segment admission.

        In paged mode admission is additionally gated on free pages: the
        queue head must fit its worst-case page reservation before it (or
        anything behind it — FIFO) is admitted or staged. Prompts longer
        than ``chunk_threshold`` take the chunked path; the rest prefill.
        Staged requests hold their worst-case reservation from staging
        time under a per-request ticket, with their first ``decode_block``
        positions' pages materialized up front — the fused segment that
        pulls them in has no host boundary at which to grow them."""
        now = time.perf_counter()
        # parked (preempted) requests re-admit ahead of everything else:
        # they are the oldest admitted work, they hold zero pages while
        # parked, and seating them first bounds how often the same request
        # gets re-preempted. Recovery teacher-forces the full prefix
        # (prompt + tokens already generated) through the chunked-prefill
        # seat, so the continuation is bit-identical to an uninterrupted
        # run; tokens generated before the preempt are re-credited to the
        # slot's emission list rather than regenerated.
        #
        # Re-admission is deliberately NOT optimistic: it waits until the
        # request's full remaining worst case sits in actually-free pages.
        # Optimistically re-admitting into the still-contended pool that
        # just evicted it is ping-pong — every bounce replays the whole
        # prefix (pure waste) before any new token lands. The hysteresis
        # costs nothing at the peak (initial admits already filled every
        # slot) and converts preempt-thrash into one park per victim.
        while self._preempted and self._free:
            p = self._preempted[0]
            npos = self._n_positions(p.req)
            if self._alloc is not None:
                first = min(npos, self.decode_block)
                if self.admission == "optimistic":
                    if self._alloc.pages_needed(npos) > self._alloc.n_free:
                        break
                elif not self._alloc.can_reserve(npos):
                    break
            self._preempted.popleft()
            slot = self._free.pop()
            if self._alloc is not None:
                self._alloc.reserve(slot, npos,
                                    strict=self.admission != "optimistic")
                if self.admission == "optimistic":
                    # materialize the first stride now so this pass's
                    # free-page accounting stays exact for the next seat
                    self._grow_slot(slot, first)
            self._seat_prefix(slot, p.prefix,
                              max(p.req.max_new_tokens - len(p.done), 1))
            self.stats["preempt_readmits"] += 1
            self._gen[slot] = list(p.done)
            self._slot_req[slot] = p.req
            self._slot_pos[slot] = 0
        # boundary fallback: seat already-staged requests into free slots
        # the loop never refilled — a slot can come back without an
        # in-loop admission (e.g. a max_new==1 prefill finishes at
        # admission and is swept at harvest), and the staged FIFO precedes
        # everything still in pending. A staged request at a boundary IS a
        # chunk admission whose pages are already reserved.
        while self._staged and self._free:
            r, ticket, bt_row = self._staged.popleft()
            slot = self._free.pop()
            if self._alloc is not None:
                self._alloc.rekey(ticket, slot)
                self._bt[slot, :] = bt_row
            r.admitted = now
            self._chunk_seat(r, slot)
            self.stats["admitted"] += 1
            self._gen[slot] = []
            self._slot_req[slot] = r
            self._slot_pos[slot] = 0
        prefills: List[Tuple[Request, int]] = []
        # no new admissions while preempted work waits: a fresh request
        # seated now would take the very pages the parked request is
        # waiting to re-earn (arrival-order inversion + another preempt
        # cycle). The parked queue drains first, always — its head fits
        # the pool by the submit()-time validation.
        while self._pending and self._free and not self._preempted:
            r = self._pending[0]
            npos = self._n_positions(r)
            chunked = self.chunk_threshold is not None and \
                len(r.prompt) > self.chunk_threshold
            if self._alloc is not None:
                if self.admission == "optimistic":
                    # expected usage: a prefill needs its prompt pages at
                    # the dispatch; a chunked prompt only its first
                    # decode_block stride. The decode tail grows lazily —
                    # under pressure the grow path preempts, never wedges.
                    first = min(npos, self.decode_block) if chunked \
                        else len(r.prompt)
                    if self._alloc.pages_needed(first) > self._alloc.n_free:
                        break
                elif not self._alloc.can_reserve(npos):
                    break
            self._pending.popleft()
            slot = self._free.pop()
            if self._alloc is not None:
                self._alloc.reserve(slot, npos,
                                    strict=self.admission != "optimistic")
                if self.admission == "optimistic":
                    # cover the expected pages now so this pass's free-page
                    # accounting stays exact for the next queue head
                    self._grow_slot(slot, first)
            r.admitted = now
            if chunked:
                self._admit_chunk(r, slot)
                self._gen[slot] = []        # first token comes via emit
                self._slot_req[slot] = r
                self._slot_pos[slot] = 0
            else:
                prefills.append((r, slot))
        groups: Dict[int, List[Tuple[Request, int]]] = {}
        for r, s in prefills:
            b = bucket_len(len(r.prompt), self.min_bucket, self.max_len)
            groups.setdefault(b, []).append((r, s))
        for b, pairs in sorted(groups.items()):
            units = [pairs] if self._group_admit else \
                [[p] for p in pairs]
            for unit in units:
                rs = [r for r, _ in unit]
                slots = [s for _, s in unit]
                firsts = self._admit_group(b, rs, slots)
                for r, s, f in zip(rs, slots, firsts):
                    self._gen[s] = [int(f)]
                    self._slot_req[s] = r
                    self._slot_pos[s] = len(r.prompt)
        # ---- staging ring: queue overflow rides into the segment ------
        while self.stage_slots and self._pending and \
                not self._preempted and \
                len(self._staged) < self.stage_slots:
            r = self._pending[0]
            npos = self._n_positions(r)
            if self._alloc is not None:
                if self.admission == "optimistic":
                    if self._alloc.pages_needed(
                            min(npos, self.decode_block)) > \
                            self._alloc.n_free:
                        break
                elif not self._alloc.can_reserve(npos):
                    break                   # FIFO: nothing jumps the line
            self._pending.popleft()
            ticket = ("stage", self._stage_seq)
            self._stage_seq += 1
            bt_row = None
            if self._alloc is not None:
                self._alloc.reserve(ticket, npos,
                                    strict=self.admission != "optimistic")
                pages = self._alloc.cover(
                    ticket, min(npos, self.decode_block))
                bt_row = np.full((self.pages_per_slot,), self.n_pages,
                                 np.int32)
                bt_row[:len(pages)] = pages
            self._staged.append((r, ticket, bt_row))
            self.stats["staged"] += 1

    # ------------------------------------------------------------------
    # preemption: park / pick victim / relieve pressure
    def _preempt_slot(self, v: int) -> None:
        """Preempt ``v``'s occupant: free its pages, park the request
        host-side with its prompt plus every token generated so far, and
        deactivate the slot on device. Host-boundary only (between
        dispatches)."""
        r = self._slot_req[v]
        done = self._gen.pop(v)[: r.max_new_tokens]
        prefix = np.concatenate([np.asarray(r.prompt, np.int32),
                                 np.asarray(done, np.int32)])
        r.preemptions += 1
        self.stats["preemptions"] += 1
        self._slot_req[v] = None
        self._free.append(v)
        if self._alloc is not None:
            self._alloc.release(v)
            self._bt[v, :] = self.n_pages
        self._preempted.append(_Parked(r, prefix.astype(np.int32),
                                       list(done)))
        # rem == 0 deactivates the slot: the next fused segment neither
        # advances it, emits for it, nor logs a completion for it (and in
        # paged mode its sentinel block-table row drops any KV write)
        self._rem = jnp.asarray(self._rem).at[v].set(0)

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Choose a live slot to preempt (never ``exclude``, the slot
        whose growth triggered the pressure). ``slack`` preempts the
        request that can best afford the round trip: most slack = deadline
        minus elapsed minus estimated remaining service (positions left x
        EWMA step time), no-SLO requests counting as infinite slack, ties
        broken toward never-yet-preempted then longest-remaining — a slot
        mid-way through replaying a preempted prefix resets its position
        counter, so without the preemption-count tie-break it *looks* like
        the longest-remaining candidate and the same request bounces
        between park and replay while fresh requests sail through. ``lru``
        preempts the most recently admitted request (vLLM-style recompute:
        the youngest has the least work to replay)."""
        cands = [s for s, r in enumerate(self._slot_req)
                 if r is not None and s != exclude]
        if not cands:
            return None
        if self.preempt_policy == "lru":
            return max(cands,
                       key=lambda s: (self._slot_req[s].admitted, s))
        now = time.perf_counter()

        def slack(s: int):
            r = self._slot_req[s]
            left = max(self._n_positions(r) - int(self._slot_pos[s]), 1)
            est = left * self._step_est
            sl = float("inf") if r.slo is None \
                else (r.arrival + r.slo) - now - est
            return (sl, -r.preemptions, left, s)

        return max(cands, key=slack)

    def _relieve_pressure(self, protect: int) -> bool:
        """Free pages under pressure, cheapest first: un-stage the newest
        staged request (zero work lost — it returns to the head of
        pending, FIFO preserved), then preempt a live victim. Returns
        False when nothing is left to free."""
        if self._staged:
            r, ticket, _bt_row = self._staged.pop()
            if self._alloc is not None:
                self._alloc.release(ticket)
            self._pending.appendleft(r)
            return True
        v = self._pick_victim(exclude=protect)
        if v is None:
            return False
        self._preempt_slot(v)
        return True

    def preempt(self, slot: int) -> None:
        """Forcibly preempt the request in ``slot`` (fault injection and
        tests; the engine preempts on its own under page pressure): park
        it and free its resources. It re-admits through the teacher-forced
        replay path with a bit-identical continuation. Call between
        ``step()`` boundaries only."""
        if not self._chunk_ok:
            raise ValueError(
                f"family {self.model.cfg.family!r} cannot recover a "
                "preempted request (no teacher-forced replay path)")
        if not 0 <= slot < self.max_batch or self._slot_req[slot] is None:
            raise ValueError(f"slot {slot} is not live")
        self._preempt_slot(slot)

    def _retire_slot(self, slot: int, r: Request, now: float) -> None:
        """Finish ``slot``'s current occupant: hand it its tokens, free its
        pages. The caller decides what happens to the slot next (freed, or
        re-occupied by a staged request the segment pulled in)."""
        r.tokens = np.asarray(
            self._gen.pop(slot)[: r.max_new_tokens], np.int32)
        r.latency = now - r.arrival
        self.stats["tokens_generated"] += len(r.tokens)
        self._slot_req[slot] = None
        if self._alloc is not None:
            # pages return to the pool the moment a sequence ends
            self._alloc.release(slot)
            self._bt[slot, :] = self.n_pages
        self._completed.append(r)

    def step(self) -> int:
        """One engine step: admit pending requests into free slots (staging
        the overflow into the device ring), run one fused decode segment,
        harvest finished slots — decoding the segment's completion log to
        split each slot's emission row between its successive occupants.
        Returns the number of decode steps executed (0 when idle)."""
        self._admit_pending()
        live = sum(r is not None for r in self._slot_req)
        if live == 0:
            return 0
        self.stats["peak_concurrency"] = max(
            self.stats["peak_concurrency"], live)
        if self._alloc is not None:
            # append pages ahead of the segment: each active slot's pos
            # advances by at most decode_block positions before the next
            # host boundary. Worst-case reservations pre-fund every cover;
            # optimistic admission can find the pool dry here, in which
            # case pressure relief un-stages queued work and then preempts
            # the slackest victim until the grow fits (it always does
            # eventually: a lone validated request fits the pool).
            for s, r in enumerate(self._slot_req):
                if r is None:
                    continue
                cover = min(int(self._slot_pos[s]) + self.decode_block,
                            self._n_positions(r))
                if not self._alloc.can_cover(s, cover):
                    self.stats["pressure_stalls"] += 1
                    while not self._alloc.can_cover(s, cover):
                        if not self._relieve_pressure(protect=s):
                            break
                self._grow_slot(s, cover)
        decode = self._get_decode()
        R = max(self.stage_slots, 1)
        ring_tok = np.zeros((R, self.max_len), np.int32)
        ring_plen = np.zeros((R,), np.int32)
        ring_new = np.zeros((R,), np.int32)
        ring_bt = KV.sentinel_block_table(
            R, self.pages_per_slot, self.n_pages) if self._paged else None
        for j, (r, _ticket, bt_row) in enumerate(self._staged):
            ring_tok[j, :len(r.prompt)] = r.prompt
            ring_plen[j] = len(r.prompt)
            ring_new[j] = max(r.max_new_tokens, 1)
            if ring_bt is not None:
                ring_bt[j] = bt_row
        args = [self.params, self._cache, self._tok, self._pos, self._rem,
                self._plen, self._pbuf, ring_tok, ring_plen, ring_new,
                np.int32(len(self._staged))]
        if self._paged:
            args += [self._bt, ring_bt]
        t_seg = time.perf_counter()
        (self._cache, self._tok, self._pos, self._rem, self._plen,
         self._pbuf, out, comp_slot, comp_step, comp_adm, n_comp,
         busy_steps, n_steps) = decode(*args)
        self.stats["decode_dispatches"] += 1
        out_np = np.asarray(out)                     # the one host sync
        comp_slot = np.asarray(comp_slot)
        comp_step = np.asarray(comp_step)
        comp_adm = np.asarray(comp_adm)
        n_comp = int(n_comp)
        n_steps = int(n_steps)
        if n_steps:
            # EWMA per-step wall time (all slots advance in lockstep):
            # feeds the slack policy's remaining-service estimate
            per = (time.perf_counter() - t_seg) / n_steps
            self._step_est = per if self._step_est == 0.0 \
                else 0.8 * self._step_est + 0.2 * per
        self._slot_pos = np.asarray(self._pos).astype(np.int64)
        self.stats["decode_steps"] += n_steps
        self.stats["busy_slot_steps"] += int(busy_steps)
        self.stats["bubble_slot_steps"] += \
            n_steps * self.max_batch - int(busy_steps)
        now = time.perf_counter()
        # completion log, in segment order: each record closes the slot's
        # current occupant over out[slot, consumed:step+1]; an "admitted"
        # record then seats the next staged request (device admission is
        # FIFO over the ring, mirrored by popping self._staged in order)
        consumed = np.zeros((self.max_batch,), np.int64)
        for j in range(n_comp):
            s = int(comp_slot[j])
            t = int(comp_step[j])
            r = self._slot_req[s]
            row = out_np[s, consumed[s]:t + 1]
            self._gen[s].extend(int(x) for x in row[row >= 0])
            consumed[s] = t + 1
            self._retire_slot(s, r, now)
            if comp_adm[j]:
                nr, ticket, bt_row = self._staged.popleft()
                if self._alloc is not None:
                    self._alloc.rekey(ticket, s)
                    self._bt[s, :] = bt_row
                nr.admitted = now
                self._slot_req[s] = nr
                self._gen[s] = []
                self.stats["admitted"] += 1
                self.stats["inseg_admissions"] += 1
            else:
                self._free.append(s)
        for s, r in enumerate(self._slot_req):
            if r is None:
                continue
            row = out_np[s, consumed[s]:]
            self._gen[s].extend(int(x) for x in row[row >= 0])
        # a prefilled request with max_new == 1 is complete at admission
        # (its only token came from prefill, rem == 0): it never passes
        # through the loop's refill logic, so sweep it here
        rem_np = np.asarray(self._rem)
        for s, r in enumerate(self._slot_req):
            if r is not None and rem_np[s] == 0:
                self._retire_slot(s, r, now)
                self._free.append(s)
        return n_steps

    def drain_completions(self) -> List[Request]:
        """Return (and clear) the requests completed since the last drain."""
        out, self._completed = self._completed, []
        return out

    @property
    def occupancy(self) -> Dict[str, float]:
        """Derived occupancy metrics over all fused segments so far:
        slot-busy fraction (active vs total slot-steps inside segments),
        in-segment admissions per segment, and the absolute bubble (idle
        slot-step) count. ``EngineExecutor`` snapshots deltas of these
        per run into its decision log."""
        busy = self.stats["busy_slot_steps"]
        bubble = self.stats["bubble_slot_steps"]
        segs = self.stats["decode_dispatches"]
        total = busy + bubble
        return {
            "slot_busy_frac": busy / total if total else 0.0,
            "admissions_per_segment":
                self.stats["inseg_admissions"] / segs if segs else 0.0,
            "bubble_slot_steps": float(bubble),
            "segments": float(segs),
        }

    def serve(self, reqs: Sequence[Request]) -> List[Request]:
        """Serve requests to completion: a thin closed loop over the
        open-loop core (submit all, step until done).

        Safe to interleave with open-loop use of the same engine: the loop
        stops once *these* requests are done, and completions of requests
        submitted by other callers stay queued for their
        ``drain_completions()``."""
        for r in reqs:
            self._validate(r)
        for r in reqs:
            self.submit(r)
        while self.busy and any(r.tokens is None for r in reqs):
            self.step()
        mine = {id(r) for r in reqs}
        self._completed = [r for r in self._completed
                           if id(r) not in mine]
        return list(reqs)

    # Legacy wave API (the JaxExecutor calibration path and older callers).
    def run_wave(self, reqs: Sequence[Request]) -> List[Request]:
        return self.serve(reqs)


# Explicit alias: the continuous engine is the default data plane.
ContinuousEngine = ServingEngine


class WaveEngine:
    """Seed-style run-to-completion wave engine (benchmark baseline).

    One prefill + per-token decode dispatches with a host sync every step;
    pads every wave to its longest prompt and decodes to the longest
    max_new; compiles per distinct (batch, prompt_len) shape. Kept verbatim
    (minus dead knobs) so ``benchmarks/fig_engine_throughput.py`` can
    measure the continuous engine against it.
    """

    def __init__(self, model: Model, params: Any, max_batch: int = 8,
                 pad_to: int = 32):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.pad_to = pad_to
        self.stats: Dict[str, int] = {"prefill_traces": 0,
                                      "decode_traces": 0}

        def _prefill(p, b):
            self.stats["prefill_traces"] += 1
            return model.prefill(p, b)

        def _decode(p, c, t, pos):
            self.stats["decode_traces"] += 1
            return model.decode(p, c, t, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _pad_cache(self, cache, batch: int, max_len: int):
        shapes = self.model.cache_shapes(batch, max_len, enc_len=self.pad_to)

        def pad(c, tgt):
            if c.shape == tgt.shape:
                return c.astype(tgt.dtype)
            pads = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
            return jnp.pad(c, pads).astype(tgt.dtype)
        return jax.tree.map(pad, cache, shapes)

    def run_wave(self, reqs: Sequence[Request]) -> List[Request]:
        """Serve one batch of requests to completion (greedy decoding)."""
        t0 = time.perf_counter()
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((B, plen, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
        logits, cache = self._prefill(self.params, batch)
        max_new = max(r.max_new_tokens for r in reqs)
        cache = self._pad_cache(cache, B, plen + max_new)
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok, plen + t)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)[:, None]
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            r.tokens = out[i, : r.max_new_tokens]
            r.latency = dt
        return list(reqs)

    def serve(self, reqs: Sequence[Request]) -> List[Request]:
        """Adaptive batching across waves of at most max_batch requests."""
        done: List[Request] = []
        pending = list(reqs)
        while pending:
            wave, pending = pending[: self.max_batch], \
                pending[self.max_batch:]
            done.extend(self.run_wave(wave))
        return done


class JaxExecutor:
    """Real executor for INFaaS workers: variant -> (engine, measured t(b)).

    Loads reduced-config models for the variants' architectures (host-sized)
    and measures actual wall-clock service times, which calibrate the
    simulator's profile-driven executor. ``execute`` warms the engine's
    compile caches for the request shape first, so measured service times
    are pure execution (the seed paid XLA compile time inside measurement).
    """

    def __init__(self, arch_cfgs: Dict[str, ArchConfig], seed: int = 0,
                 **engine_kwargs):
        self.engines: Dict[str, ServingEngine] = {}
        # keyed on (arch, batch, prompt_len): mixed-length calibration runs
        # are distinct measurements and must not overwrite each other
        self.measured: Dict[Tuple[str, int, int], float] = {}
        rng = jax.random.PRNGKey(seed)
        for name, cfg in arch_cfgs.items():
            model = build_model(cfg)
            params = model.init(rng)
            self.engines[name] = ServingEngine(model, params,
                                               **engine_kwargs)

    def execute(self, arch: str, batch: int, prompt_len: int = 8,
                max_new: int = 4) -> float:
        eng = self.engines[arch]
        eng.warmup(prompt_lens=[prompt_len])
        reqs = [Request(rid=i, prompt=np.arange(prompt_len) % 7,
                        max_new_tokens=max_new) for i in range(batch)]
        t0 = time.perf_counter()
        eng.serve(reqs)
        dt = time.perf_counter() - t0
        self.measured[(arch, batch, prompt_len)] = dt
        return dt
