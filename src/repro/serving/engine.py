"""Real-execution serving data plane: continuous-batching, device-resident
decode engine with shape bucketing.

This is the data plane behind a ``JaxExecutor`` worker: the INFaaS control
plane picks the variant; this engine actually runs it. The design replaces
the seed's run-to-completion waves (one device dispatch *and one host sync
per generated token*, one XLA compile per distinct ``(batch, prompt_len)``)
with three mechanisms:

**Slot scheduler (continuous batching).** The engine owns a preallocated
max-shape KV cache of ``max_batch`` slots x ``max_len`` positions plus
per-slot ``tok``/``pos``/``remaining`` arrays, all device-resident. A
request is admitted by prefilling its prompt (batch 1, right-padded to a
bucket) and inserting the resulting cache into a free slot via
``dynamic_update_slice`` along each leaf's batch axis — there is no
post-prefill ``_pad_cache`` copy of the whole batch. Slots are freed the
moment their sequence finishes and refilled from the pending queue between
decode segments, so short requests never wait for the longest request in a
wave.

**Fused decode segments.** Decoding runs as a ``lax.while_loop`` over
``model.decode`` inside one jitted function: up to ``decode_block`` tokens
for all slots are generated in a single device dispatch with a single
host sync at the end (the seed engine synced every token). Each slot
carries its own position vector (``decode``'s per-sequence ``pos``) and an
activity mask; finished slots stop advancing, and the loop exits early
when every slot is done, so drained batches stop costing FLOPs.

**Shape bucketing + warmup.** Prompt lengths are padded up to power-of-two
buckets (>= ``min_bucket``, <= ``max_len``) and admit batches are bucketed
to {1, max_batch} (same-bucket prompts admitted in one dispatch; padding
rows scatter out of bounds and are dropped), with prefill executables
keyed on the (bucket_batch, bucket_len) pair — a mixed-length request
stream compiles at most two prefills per prompt bucket and exactly one
decode-segment program per engine.
``warmup(prompt_lens=...)`` triggers those compiles eagerly so calibration
(``JaxExecutor``) and latency-sensitive serving never pay compile time
inside a measured service time. ``stats`` counts actual retraces
(``prefill_traces`` / ``decode_traces``), which tests pin down.

**Open-loop core.** The engine is step-driven: state (slot occupancy,
pending queue, per-slot generations) persists on the engine, and the three
phases of the serving loop are separately callable —

* ``submit(req)``     enqueue a request (at any time, including while other
  requests are mid-decode); its latency clock starts at ``Request.arrival``
  (stamped at submit if unset),
* ``step()``          admit pending requests into free slots, run ONE fused
  decode segment, harvest finished slots,
* ``drain_completions()``  collect requests finished since the last drain.

Mid-stream admission falls out: a request submitted between segments joins
the next ``step()`` without restarting in-flight slots. ``serve()`` is a
thin closed loop over the core (submit all, step until idle) and produces
bit-identical outputs and identical trace/dispatch counts to the closed
PR-1 loop. The open seam is what lets the INFaaS control plane
(``EngineExecutor`` in ``repro.serving.executor``) drive real engines.

Exactness: for the dense/hybrid/ssm (and, by the same causal-masking
argument, vlm) families the engine emits token-for-token the same greedy
outputs as a serial per-request prefill+decode (prompts are right-padded;
causal attention masks padded KV via per-sequence valid lengths, and
recurrent families mask their state updates — see ``repro.models.model``).
MoE matches serial decode except when GShard-style expert capacity —
a static function of the padded token count — crosses a boundary between
the prompt's bucket and its exact length and flips a token-drop decision
(see ``prefill_moe``); MoE prompts are therefore admitted one per
dispatch, which keeps decode exact and confines the effect to prefill.
The audio family inherits the seed's unmasked cross-attention over
zero-padded encoder KV, so its outputs depend on the engine's ``max_len``
exactly as they depended on the seed's ``pad_to``.

The seed wave engine survives as ``WaveEngine`` — the benchmark baseline
for ``benchmarks/fig_engine_throughput.py``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 8
    arrival: float = 0.0
    tokens: Optional[np.ndarray] = None
    latency: float = 0.0


def bucket_len(n: int, minimum: int = 8, maximum: Optional[int] = None) -> int:
    """Round ``n`` up to a power of two >= ``minimum`` (clamped to maximum)."""
    b = max(minimum, 1 << max(int(n) - 1, 0).bit_length())
    if maximum is not None:
        if n > maximum:
            raise ValueError(f"length {n} exceeds engine max_len {maximum}")
        b = min(b, maximum)
    return b


class ServingEngine:
    """Continuous-batching engine over one model + params (greedy decode)."""

    def __init__(self, model: Model, params: Any, max_batch: int = 8,
                 max_len: int = 128, decode_block: int = 16,
                 min_bucket: int = 8):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.decode_block = decode_block
        self.min_bucket = min_bucket
        # MoE expert capacity is a function of the co-batched token count,
        # so grouped admission could change token-drop decisions vs a
        # serial run; admit MoE prompts one per dispatch to stay exact.
        self._group_admit = model.cfg.family != "moe"
        self.stats: Dict[str, int] = {
            "prefill_traces": 0, "decode_traces": 0,
            "prefill_dispatches": 0, "decode_dispatches": 0,
            "decode_steps": 0, "tokens_generated": 0, "admitted": 0,
        }
        shapes = model.cache_shapes(max_batch, max_len, enc_len=max_len)
        self._cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        self._tok = jnp.zeros((max_batch, 1), jnp.int32)
        self._pos = jnp.zeros((max_batch,), jnp.int32)
        self._rem = jnp.zeros((max_batch,), jnp.int32)
        # Per-leaf batch axis, found by diffing cache shapes at two batch
        # sizes (family-agnostic: attention caches, SSM/conv states, and
        # grouped VLM layouts all place batch differently).
        s2 = model.cache_shapes(2, max_len, enc_len=max_len)
        s3 = model.cache_shapes(3, max_len, enc_len=max_len)
        self._batch_axes = jax.tree.map(
            lambda a, b: next(i for i, (x, y) in
                              enumerate(zip(a.shape, b.shape)) if x != y),
            s2, s3)
        self._prefill_fns: Dict[int, Any] = {}
        self._decode_fn = None
        # open-loop state: persists across submit()/step() calls so
        # requests can arrive while earlier ones are mid-decode
        self._pending: deque = deque()
        self._slot_req: List[Optional[Request]] = [None] * max_batch
        self._gen: Dict[int, List[int]] = {}
        self._free: List[int] = list(range(max_batch))[::-1]
        self._completed: List[Request] = []

    # ------------------------------------------------------------------
    # compiled programs (keyed on (bucket_batch, bucket_len) shape)
    def _get_prefill(self, bucket: int, nbatch: int):
        key = (nbatch, bucket)
        fn = self._prefill_fns.get(key)
        if fn is not None:
            return fn
        model, cfg = self.model, self.model.cfg
        baxes = self._batch_axes

        def prefill_admit(params, cache, tok, pos, rem, tokens, lengths,
                          slots, max_news):
            # tokens: (nbatch, bucket); lengths/slots/max_news: (nbatch,).
            # Padding rows carry slot == max_batch: out-of-bounds scatter
            # indices are dropped, so they touch no live slot.
            self.stats["prefill_traces"] += 1   # Python side effect: runs
            batch = {"tokens": tokens,          # once per (re)trace only
                     "length": lengths}
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros((nbatch, bucket, cfg.d_model),
                                            cfg.dtype)
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (nbatch, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
            logits, pcache = model.prefill(params, batch)
            firsts = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

            def insert(slot_leaf, new_leaf, bax):
                pads = [(0, 0) if i == bax else (0, t - s)
                        for i, (s, t) in enumerate(zip(new_leaf.shape,
                                                       slot_leaf.shape))]
                new_leaf = jnp.pad(new_leaf, pads).astype(slot_leaf.dtype)
                arr = jnp.moveaxis(slot_leaf, bax, 0)
                rows = jnp.moveaxis(new_leaf, bax, 0)
                arr = arr.at[slots].set(rows, mode="drop")
                return jnp.moveaxis(arr, 0, bax)

            cache = jax.tree.map(insert, cache, pcache, baxes)
            tok = tok.at[slots].set(firsts[:, None], mode="drop")
            pos = pos.at[slots].set(lengths, mode="drop")
            rem = rem.at[slots].set(max_news - 1, mode="drop")
            return cache, tok, pos, rem, firsts

        fn = jax.jit(prefill_admit)
        self._prefill_fns[key] = fn
        return fn

    def _get_decode(self):
        if self._decode_fn is not None:
            return self._decode_fn
        model, steps, slots = self.model, self.decode_block, self.max_batch

        def decode_segment(params, cache, tok, pos, rem):
            self.stats["decode_traces"] += 1

            def cond(st):
                i = st[0]
                return (i < steps) & jnp.any(st[4] > 0)

            def body(st):
                i, cache, tok, pos, rem, out = st
                active = rem > 0
                logits, cache = model.decode(params, cache, tok, pos)
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                emit = jnp.where(active, nxt, -1)
                out = lax.dynamic_update_slice(out, emit[:, None], (0, i))
                tok = jnp.where(active[:, None], nxt[:, None], tok)
                pos = jnp.where(active, pos + 1, pos)
                rem = jnp.where(active, rem - 1, rem)
                return i + 1, cache, tok, pos, rem, out

            out0 = jnp.full((slots, steps), -1, jnp.int32)
            i, cache, tok, pos, rem, out = lax.while_loop(
                cond, body, (jnp.int32(0), cache, tok, pos, rem, out0))
            return cache, tok, pos, rem, out, i

        self._decode_fn = jax.jit(decode_segment)
        return self._decode_fn

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens: Sequence[int] = (),
               include_decode: bool = True) -> None:
        """Compile prefill executables for the (batch, length) buckets
        covering ``prompt_lens`` (plus the minimum bucket) and the decode
        segment.

        Warmup calls run against the live state with every scatter index
        out of bounds (dropped), so engine state is untouched; subsequent
        serving on these buckets never recompiles.
        """
        buckets = {bucket_len(max(n, 1), self.min_bucket, self.max_len)
                   for n in list(prompt_lens) + [1]}
        nbatches = {1, self.max_batch} if self._group_admit else {1}
        for b in sorted(buckets):
            for nb in sorted(nbatches):
                if (nb, b) in self._prefill_fns:
                    continue        # already compiled; skip the dummy run
                fn = self._get_prefill(b, nb)
                out = fn(self.params, self._cache, self._tok, self._pos,
                         self._rem, np.zeros((nb, b), np.int32),
                         np.ones((nb,), np.int32),
                         np.full((nb,), self.max_batch, np.int32),
                         np.ones((nb,), np.int32))
                jax.block_until_ready(out[-1])
        if include_decode and self._decode_fn is None:
            fn = self._get_decode()
            out = fn(self.params, self._cache, self._tok, self._pos,
                     jnp.zeros((self.max_batch,), jnp.int32))
            jax.block_until_ready(out[-1])

    # ------------------------------------------------------------------
    def _admit_group(self, bucket: int, rs: List[Request],
                     slots: List[int]) -> np.ndarray:
        """One prefill dispatch admitting same-bucket requests into slots.

        Admit batches are bucketed to {1, max_batch} so the executable
        count stays at <= 2 per prompt bucket; padding rows point their
        scatter index past the last slot and are dropped.
        """
        m = len(rs)
        nb = 1 if m == 1 else self.max_batch
        tokens = np.zeros((nb, bucket), np.int32)
        lengths = np.ones((nb,), np.int32)
        slot_idx = np.full((nb,), self.max_batch, np.int32)
        max_news = np.ones((nb,), np.int32)
        for j, (r, s) in enumerate(zip(rs, slots)):
            tokens[j, : len(r.prompt)] = r.prompt       # right-pad
            lengths[j] = len(r.prompt)
            slot_idx[j] = s
            max_news[j] = max(r.max_new_tokens, 1)
        fn = self._get_prefill(bucket, nb)
        self._cache, self._tok, self._pos, self._rem, firsts = fn(
            self.params, self._cache, self._tok, self._pos, self._rem,
            tokens, lengths, slot_idx, max_news)
        self.stats["prefill_dispatches"] += 1
        self.stats["admitted"] += m
        return np.asarray(firsts)[:m]

    # ------------------------------------------------------------------
    # open-loop core: submit / step / drain_completions
    @property
    def busy(self) -> bool:
        """True while any request is pending admission or mid-decode."""
        return bool(self._pending) or \
            any(r is not None for r in self._slot_req)

    def _validate(self, r: Request) -> None:
        if len(r.prompt) + r.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {r.rid}: prompt_len {len(r.prompt)} + max_new "
                f"{r.max_new_tokens} exceeds engine max_len "
                f"{self.max_len}")

    def submit(self, r: Request) -> None:
        """Enqueue a request; may be called at any time, including while
        other requests are mid-decode (it joins at the next ``step()``).
        The latency clock starts at ``r.arrival`` (stamped now if unset)."""
        self._validate(r)
        if r.arrival == 0.0:
            r.arrival = time.perf_counter()
        self._pending.append(r)

    def _admit_pending(self) -> None:
        """Fill free slots from the pending queue (grouped by bucket)."""
        if not (self._pending and self._free):
            return
        take = min(len(self._free), len(self._pending))
        chunk = [self._pending.popleft() for _ in range(take)]
        groups: Dict[int, List[Request]] = {}
        for r in chunk:
            b = bucket_len(len(r.prompt), self.min_bucket, self.max_len)
            groups.setdefault(b, []).append(r)
        for b, rs in sorted(groups.items()):
            units = [rs] if self._group_admit else [[r] for r in rs]
            for unit in units:
                slots = [self._free.pop() for _ in unit]
                firsts = self._admit_group(b, unit, slots)
                for r, s, f in zip(unit, slots, firsts):
                    self._gen[s] = [int(f)]
                    self._slot_req[s] = r

    def step(self) -> int:
        """One engine step: admit pending requests into free slots, run one
        fused decode segment, harvest finished slots. Returns the number of
        decode steps executed (0 when the engine is idle)."""
        self._admit_pending()
        if all(r is None for r in self._slot_req):
            return 0
        decode = self._get_decode()
        self._cache, self._tok, self._pos, self._rem, out, n_steps = \
            decode(self.params, self._cache, self._tok, self._pos,
                   self._rem)
        self.stats["decode_dispatches"] += 1
        out_np = np.asarray(out)                     # the one host sync
        rem_np = np.asarray(self._rem)
        self.stats["decode_steps"] += int(n_steps)
        now = time.perf_counter()
        for slot, r in enumerate(self._slot_req):
            if r is None:
                continue
            row = out_np[slot]
            self._gen[slot].extend(int(t) for t in row[row >= 0])
            if rem_np[slot] == 0:
                r.tokens = np.asarray(
                    self._gen.pop(slot)[: r.max_new_tokens], np.int32)
                r.latency = now - r.arrival
                self.stats["tokens_generated"] += len(r.tokens)
                self._slot_req[slot] = None
                self._free.append(slot)
                self._completed.append(r)
        return int(n_steps)

    def drain_completions(self) -> List[Request]:
        """Return (and clear) the requests completed since the last drain."""
        out, self._completed = self._completed, []
        return out

    def serve(self, reqs: Sequence[Request]) -> List[Request]:
        """Serve requests to completion: a thin closed loop over the
        open-loop core (submit all, step until done).

        Safe to interleave with open-loop use of the same engine: the loop
        stops once *these* requests are done, and completions of requests
        submitted by other callers stay queued for their
        ``drain_completions()``."""
        for r in reqs:
            self._validate(r)
        for r in reqs:
            self.submit(r)
        while self.busy and any(r.tokens is None for r in reqs):
            self.step()
        mine = {id(r) for r in reqs}
        self._completed = [r for r in self._completed
                           if id(r) not in mine]
        return list(reqs)

    # Legacy wave API (the JaxExecutor calibration path and older callers).
    def run_wave(self, reqs: Sequence[Request]) -> List[Request]:
        return self.serve(reqs)


# Explicit alias: the continuous engine is the default data plane.
ContinuousEngine = ServingEngine


class WaveEngine:
    """Seed-style run-to-completion wave engine (benchmark baseline).

    One prefill + per-token decode dispatches with a host sync every step;
    pads every wave to its longest prompt and decodes to the longest
    max_new; compiles per distinct (batch, prompt_len) shape. Kept verbatim
    (minus dead knobs) so ``benchmarks/fig_engine_throughput.py`` can
    measure the continuous engine against it.
    """

    def __init__(self, model: Model, params: Any, max_batch: int = 8,
                 pad_to: int = 32):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.pad_to = pad_to
        self.stats: Dict[str, int] = {"prefill_traces": 0,
                                      "decode_traces": 0}

        def _prefill(p, b):
            self.stats["prefill_traces"] += 1
            return model.prefill(p, b)

        def _decode(p, c, t, pos):
            self.stats["decode_traces"] += 1
            return model.decode(p, c, t, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _pad_cache(self, cache, batch: int, max_len: int):
        shapes = self.model.cache_shapes(batch, max_len, enc_len=self.pad_to)

        def pad(c, tgt):
            if c.shape == tgt.shape:
                return c.astype(tgt.dtype)
            pads = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
            return jnp.pad(c, pads).astype(tgt.dtype)
        return jax.tree.map(pad, cache, shapes)

    def run_wave(self, reqs: Sequence[Request]) -> List[Request]:
        """Serve one batch of requests to completion (greedy decoding)."""
        t0 = time.perf_counter()
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((B, plen, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
        logits, cache = self._prefill(self.params, batch)
        max_new = max(r.max_new_tokens for r in reqs)
        cache = self._pad_cache(cache, B, plen + max_new)
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok, plen + t)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)[:, None]
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            r.tokens = out[i, : r.max_new_tokens]
            r.latency = dt
        return list(reqs)

    def serve(self, reqs: Sequence[Request]) -> List[Request]:
        """Adaptive batching across waves of at most max_batch requests."""
        done: List[Request] = []
        pending = list(reqs)
        while pending:
            wave, pending = pending[: self.max_batch], \
                pending[self.max_batch:]
            done.extend(self.run_wave(wave))
        return done


class JaxExecutor:
    """Real executor for INFaaS workers: variant -> (engine, measured t(b)).

    Loads reduced-config models for the variants' architectures (host-sized)
    and measures actual wall-clock service times, which calibrate the
    simulator's profile-driven executor. ``execute`` warms the engine's
    compile caches for the request shape first, so measured service times
    are pure execution (the seed paid XLA compile time inside measurement).
    """

    def __init__(self, arch_cfgs: Dict[str, ArchConfig], seed: int = 0,
                 **engine_kwargs):
        self.engines: Dict[str, ServingEngine] = {}
        # keyed on (arch, batch, prompt_len): mixed-length calibration runs
        # are distinct measurements and must not overwrite each other
        self.measured: Dict[Tuple[str, int, int], float] = {}
        rng = jax.random.PRNGKey(seed)
        for name, cfg in arch_cfgs.items():
            model = build_model(cfg)
            params = model.init(rng)
            self.engines[name] = ServingEngine(model, params,
                                               **engine_kwargs)

    def execute(self, arch: str, batch: int, prompt_len: int = 8,
                max_new: int = 4) -> float:
        eng = self.engines[arch]
        eng.warmup(prompt_lens=[prompt_len])
        reqs = [Request(rid=i, prompt=np.arange(prompt_len) % 7,
                        max_new_tokens=max_new) for i in range(batch)]
        t0 = time.perf_counter()
        eng.serve(reqs)
        dt = time.perf_counter() - t0
        self.measured[(arch, batch, prompt_len)] = dt
        return dt
