"""Real-execution serving engine (host JAX): adaptive batching + prefill/
decode waves against compiled model functions.

This is the data plane behind a ``JaxExecutor`` worker: the INFaaS control
plane picks the variant; this engine actually runs it. Requests are packed
into waves of at most ``max_batch`` (adaptive batching), prompts are padded
to a shared length, then decoded step-by-step with a shared KV cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 8
    arrival: float = 0.0
    tokens: Optional[np.ndarray] = None
    latency: float = 0.0


class ServingEngine:
    def __init__(self, model: Model, params: Any, max_batch: int = 8,
                 pad_to: int = 32, dtype=jnp.int32):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.pad_to = pad_to
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode)
        self._cache_tpl = None

    # ------------------------------------------------------------------
    def _pad_cache(self, cache, batch: int, max_len: int):
        shapes = self.model.cache_shapes(batch, max_len,
                                         enc_len=self.pad_to)

        def pad(c, tgt):
            if c.shape == tgt.shape:
                return c.astype(tgt.dtype)
            pads = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
            return jnp.pad(c, pads).astype(tgt.dtype)
        return jax.tree.map(pad, cache, shapes)

    def run_wave(self, reqs: Sequence[Request]) -> List[Request]:
        """Serve one batch of requests to completion (greedy decoding)."""
        t0 = time.perf_counter()
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((B, plen, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
        logits, cache = self._prefill(self.params, batch)
        max_new = max(r.max_new_tokens for r in reqs)
        cache = self._pad_cache(cache, B, plen + max_new)
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for t in range(max_new):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok, plen + t)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)[:, None]
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            r.tokens = out[i, : r.max_new_tokens]
            r.latency = dt
        return list(reqs)

    def serve(self, reqs: Sequence[Request]) -> List[Request]:
        """Adaptive batching across waves of at most max_batch requests."""
        done: List[Request] = []
        pending = list(reqs)
        while pending:
            wave, pending = pending[: self.max_batch], \
                pending[self.max_batch:]
            done.extend(self.run_wave(wave))
        return done


class JaxExecutor:
    """Real executor for INFaaS workers: variant -> (engine, measured t(b)).

    Loads reduced-config models for the variants' architectures (host-sized)
    and measures actual wall-clock service times, which calibrate the
    simulator's profile-driven executor.
    """

    def __init__(self, arch_cfgs: Dict[str, ArchConfig], seed: int = 0):
        self.engines: Dict[str, ServingEngine] = {}
        self.measured: Dict[Tuple[str, int], float] = {}
        rng = jax.random.PRNGKey(seed)
        for name, cfg in arch_cfgs.items():
            model = build_model(cfg)
            params = model.init(rng)
            self.engines[name] = ServingEngine(model, params)

    def execute(self, arch: str, batch: int, prompt_len: int = 8,
                max_new: int = 4) -> float:
        eng = self.engines[arch]
        reqs = [Request(rid=i, prompt=np.arange(prompt_len) % 7,
                        max_new_tokens=max_new) for i in range(batch)]
        t0 = time.perf_counter()
        eng.run_wave(reqs)
        dt = time.perf_counter() - t0
        self.measured[(arch, batch)] = dt
        return dt
