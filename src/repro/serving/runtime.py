"""Wall-clock serving runtime: the INFaaS control plane as a live server.

Under the virtual clock the control plane is a simulation harness — workers
resolve a job's service time synchronously (``Executor.run``) and advance
the ``EventLoop`` by that measured duration, and ``QueryHandle.result``
pumps the loop. This module supplies the two pieces that turn the same
control plane into a long-running server on ``RealClock``:

``ThreadedEngineExecutor``
    An ``EngineExecutor`` whose jobs run on a background *stepper thread*
    instead of blocking the caller: ``run_async(variant, batch, requests,
    on_done)`` enqueues the job and returns immediately; the stepper
    drives ``submit()/step()/drain_completions()`` continuously across all
    live engines, co-batching concurrent jobs that target the same
    variant, forwarding per-segment partial outputs to each query's
    ``on_tokens`` sink as they are harvested (time-to-first-token), and
    firing ``on_done(measured_service_time)`` when every request of a job
    has retired. The worker (``Worker._start_async``) marshals that
    completion back onto the clock's scheduler thread, so all control-
    plane state changes still happen one callback at a time.

``ServingRuntime``
    The client-facing wrapper over a wall-clock cluster: thread-safe
    ``submit`` (marshaled onto the scheduler thread, where the master's
    selection/dispatch runs like any other clock callback), bookkeeping of
    in-flight handles, and ``shutdown(drain=True)`` which waits for
    in-flight queries to stream out, stops the stepper threads, and stops
    the clock — the SIGINT path of ``launch/serve.py --clock wall``.

Thread model (three kinds of threads, one lock each):

    client threads ──submit()──► RealClock scheduler thread (control
        plane: master dispatch, worker bookkeeping, completions)
    scheduler thread ──run_async()──► stepper thread (data plane: engine
        step/drain; owns the executor lock)
    stepper thread ──on_tokens──► QueryHandle (handle condition variable;
        chunks stream without touching the control plane)
    stepper thread ──on_done──► loop.schedule(0, ...) ──► scheduler thread
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.worker import ExecRequest
from repro.serving.engine import Request
from repro.serving.executor import EngineExecutor, EngineExecutorConfig


class _WallJob:
    """One ``run_async`` job in flight on the stepper thread."""
    __slots__ = ("variant", "batch", "eng", "groups", "on_done", "t0",
                 "occ0", "outstanding", "synthetic")

    def __init__(self, variant, batch, eng, groups, on_done, t0, occ0):
        self.variant = variant
        self.batch = batch
        self.eng = eng
        self.groups: List[Tuple[ExecRequest, List[Request]]] = groups
        self.on_done = on_done
        self.t0 = t0
        self.occ0 = occ0
        self.outstanding = sum(len(ers) for _, ers in groups)
        self.synthetic = not any(er.prompts for er, _ in groups)


class ThreadedEngineExecutor(EngineExecutor):
    """EngineExecutor stepped by a background thread (wall-clock mode).

    The synchronous ``run`` path is inherited unchanged (tests and the
    virtual clock keep using it); ``run_async`` is the non-blocking
    entry the worker prefers when present. One stepper thread per
    executor: jobs for the same variant co-batch on that variant's
    engine (continuous batching across control-plane jobs), jobs for
    different variants interleave step-by-step.
    """

    def __init__(self, arch_cfgs, cfg: EngineExecutorConfig =
                 EngineExecutorConfig(), model_cache=None):
        # the LRU engine cap assumes engines are idle between run()
        # calls; a threaded executor's engines hold in-flight slots, so
        # eviction is disabled rather than risking a live engine
        if cfg.max_engines is not None:
            cfg = dataclasses.replace(cfg, max_engines=None)
        super().__init__(arch_cfgs, cfg, model_cache=model_cache)
        self._queue: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._active: List[_WallJob] = []
        self._sinks: Dict[int, Tuple[ExecRequest, int]] = {}
        self._req_job: Dict[int, _WallJob] = {}
        self._thread: Optional[threading.Thread] = None
        self._stopping = False

    # ------------------------------------------------------------------
    def run_async(self, variant, batch: int,
                  requests: Optional[List[ExecRequest]],
                  on_done: Callable[..., None]) -> None:
        """Enqueue one job for the stepper thread; returns immediately.
        ``on_done(duration_s)`` fires from the stepper thread when the
        job's last request retires; ``on_done(0.0, error)`` on rejection
        (e.g. a prompt exceeding the engine's max_len)."""
        if self._stopping:
            raise RuntimeError("executor is shutting down")
        self._queue.put((variant, batch, requests, on_done))
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._step_loop, name="engine-stepper", daemon=True)
            self._thread.start()

    def shutdown(self, timeout: float = 30.0) -> None:
        """Drain: finish every queued/in-flight job, then stop the
        stepper thread. New ``run_async`` calls are rejected."""
        self._stopping = True
        self._queue.put(None)          # wake the stepper
        th = self._thread
        if th is not None and th.is_alive():
            th.join(timeout)

    # ------------------------------------------------------------------
    def _admit_job(self, item: tuple) -> None:
        variant, batch, requests, on_done = item
        try:
            with self._lock:
                eng = self._engine(variant)
                vocab = self.arch_cfgs[variant.arch].vocab
                if not requests:
                    requests = [ExecRequest(n_inputs=max(int(batch), 1))]
                real_lens = [len(p) for er in requests for p in er.prompts]
                if real_lens:
                    eng.warmup(prompt_lens=real_lens)
                t0 = time.perf_counter()
                groups: List[Tuple[ExecRequest, List[Request]]] = []
                for er in requests:
                    groups.append((er, self._make_requests(er, vocab, t0)))
                # validate everything before submitting anything, so a
                # rejected job never leaves half its prompts in the engine
                for _, ers in groups:
                    for r in ers:
                        eng._validate(r)
                occ0 = {k: eng.stats[k] for k in self._OCC_KEYS}
                job = _WallJob(variant, int(batch), eng, groups, on_done,
                               t0, occ0)
                for er, ers in groups:
                    for i, r in enumerate(ers):
                        eng.submit(r)
                        self._sinks[id(r)] = (er, i)
                        self._req_job[id(r)] = job
                self._active.append(job)
        except Exception as e:  # noqa: BLE001 - reported through on_done
            on_done(0.0, e)

    def _finish_request(self, r: Request) -> None:
        job = self._req_job.pop(id(r), None)
        self._sinks.pop(id(r), None)
        if job is None:
            return
        job.outstanding -= 1
        if job.outstanding > 0:
            return
        dt = time.perf_counter() - job.t0
        self._active.remove(job)
        # NOTE: co-batched jobs overlap on one engine, so each job's
        # occupancy delta also covers segments it shared — the log is a
        # decision log, not an exact per-job cost attribution
        self._record_occupancy(job.variant, job.batch, dt, job.occ0,
                               job.eng)
        for er, ers in job.groups:
            self._deliver(er, ers)
        if job.synthetic:
            n = max(sum(len(ers) for _, ers in job.groups), 1)
            self._observe(job.variant, n, dt)
        job.on_done(dt)

    def _step_loop(self) -> None:
        while True:
            # pull new work: block briefly only when fully idle, so an
            # idle executor doesn't spin and a busy one doesn't stall
            block = not self._active
            try:
                item = self._queue.get(timeout=0.05) if block \
                    else self._queue.get_nowait()
                while item is not None:
                    self._admit_job(item)
                    item = self._queue.get_nowait()
            except queue.Empty:
                pass
            if not self._active:
                if self._stopping and self._queue.empty():
                    return
                continue
            engines = []
            with self._lock:
                for job in self._active:
                    if job.eng not in engines:
                        engines.append(job.eng)
            for eng in engines:
                with self._lock:
                    if eng.busy:
                        eng.step()
                        self._pump_stream(eng, self._sinks)
                    for r in eng.drain_completions():
                        self._finish_request(r)


class ServingRuntime:
    """Client surface of a wall-clock cluster (``make_cluster(...,
    clock="wall")``): thread-safe submission and drain-on-shutdown.

    ``submit(spec)`` may be called from any thread: the master's
    selection/dispatch is marshaled onto the ``RealClock`` scheduler
    thread (where every other control-plane callback runs) and the
    resulting ``QueryHandle`` is handed back. The handle then works as
    documented in ``core.api`` — ``result()`` blocks on its condition
    variable, ``on_tokens``/``iter_tokens`` stream live.
    """

    def __init__(self, cluster):
        if getattr(cluster.loop, "virtual", True):
            raise ValueError("ServingRuntime needs a wall-clock cluster "
                             "(make_cluster(..., clock='wall'))")
        self.cluster = cluster
        self.loop = cluster.loop
        self._inflight: List[Any] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def submit(self, spec, timeout: float = 30.0):
        """Submit from any thread; returns the ``QueryHandle``."""
        box: Dict[str, Any] = {}
        ev = threading.Event()

        def do():
            try:
                box["handle"] = self.cluster.api.submit(spec)
            except Exception as e:  # noqa: BLE001 - re-raised to caller
                box["error"] = e
            ev.set()

        self.loop.schedule(0.0, do)
        if not ev.wait(timeout):
            raise TimeoutError("control plane did not accept the query "
                               f"within {timeout}s")
        if "error" in box:
            raise box["error"]
        handle = box["handle"]
        with self._lock:
            self._inflight.append(handle)
        return handle

    def drain(self, timeout: float = 30.0) -> bool:
        """Wait until every submitted query has completed; False if the
        deadline passed with work still in flight."""
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                self._inflight = [h for h in self._inflight if not h.done]
                n = len(self._inflight)
            if n == 0:
                return True
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.01)

    def shutdown(self, drain: bool = True, timeout: float = 30.0) -> bool:
        """Stop serving: optionally drain in-flight queries, then stop
        stepper threads and the clock. Returns True on a clean drain."""
        ok = self.drain(timeout) if drain else True
        for ex in getattr(self.cluster, "executors", []):
            stop = getattr(ex, "shutdown", None)
            if stop is not None:
                stop()
        self.loop.shutdown()
        return ok
