"""EngineExecutor: the real data plane behind an INFaaS worker device.

Implements the worker's ``Executor`` protocol (``repro.core.worker``) over
per-variant continuous-batching ``ServingEngine`` instances, so the whole
control plane — per-query variant selection, adaptive batching, the
monitoring daemon, and two-level autoscaling — drives *live* JAX engines
instead of the profile-driven simulation:

* ``run(variant, batch, requests)`` builds (lazily) a reduced-config
  engine for the variant, pushes the batch through the open-loop
  ``submit()``/``step()``/``drain_completions()`` core, and returns the
  measured wall-clock service time. That measured time becomes the job's
  duration on the worker's (virtual) clock, so queueing, utilization, and
  autoscaling decisions all reflect real execution speed.

* each ``ExecRequest`` in ``requests`` is one co-batched query: when it
  carries real payload prompts, every prompt becomes one
  ``serving.engine.Request`` and the generated token ids are handed back
  through the request's ``on_outputs`` sink (one array per prompt, in
  submission order) — a payload-carrying ``QuerySpec`` is served on its
  *actual* inputs, not synthetic stand-ins. Requests without prompts fall
  back to the synthetic shape (``prompt_len``/``max_new`` below), which
  keeps compile caches to one prefill bucket for pure-accounting load.

* every measurement is recorded per batch size, and once two distinct
  batch sizes have been observed the variant's ``VariantProfile`` is
  re-fit in place (``repro.core.profiler.refit_profile``): t(b) = m*b + c
  moves from the analytic roofline guess to calibrated reality, and
  selection improves as measurements accumulate (ROADMAP item: wire
  measured t(b) back into the variant profiles).

Model weights are built once per architecture and shared across the
variants (and, via ``model_cache``, across the cluster's workers); each
variant still gets its own engine so slot state never crosses variants.
Engines are warmed up at creation, keeping XLA compile time out of the
measured service times. With ``max_engines`` set, the per-variant engine
map is an LRU: the least-recently-run variant's engine is dropped when the
cap is hit (engines are idle between ``run()`` calls, so nothing in flight
is lost) and rebuilds lazily — warmup happens at rebuild, outside the
measured window — keeping multi-arch ``backend="real"`` clusters
host-sized. ``page_size``/``n_pages``/``chunk_threshold``/``stage_slots``
pass through to the engines: the paged KV data plane, chunked prefill,
and in-segment admission under the full INFaaS control plane. Each
``run()`` appends a record to ``occupancy_log`` — the executor's decision
log — with the run's fused-segment occupancy (slot-busy fraction,
in-segment admissions per segment, bubble slot-steps) and its preemption
/ pressure-stall counts under optimistic admission, so the control plane
can see both how densely the data plane is packing its hardware and what
that packing cost in preempted work. ``ExecRequest.slo`` threads each
query's latency objective down to the engine's SLO-aware victim choice,
and ``ExecRequest.on_report`` carries the degradation verdict (was any
of this query's work preempted?) back up to the worker.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import profiler as prof
from repro.core.abstraction import Variant
from repro.core.worker import ExecRequest
from repro.serving.engine import Request, ServingEngine


@dataclasses.dataclass
class EngineExecutorConfig:
    """Reduced-scale engine + synthetic request shape for real execution."""
    max_batch: int = 4          # engine slots (admission queues past this)
    max_len: int = 32
    decode_block: int = 4
    min_bucket: int = 4
    prompt_len: int = 6         # synthetic request shape (fixed -> one
    max_new: int = 3            # prefill bucket, zero steady-state compiles)
    refit_min_points: int = 2   # distinct batch sizes before an m,c refit
    obs_window: int = 32        # measurements kept per (variant, batch)
    seed: int = 0
    page_size: Optional[int] = None   # paged KV cache (None = contiguous)
    n_pages: Optional[int] = None     # pool size (None = slot parity)
    chunk_threshold: Optional[int] = None  # chunked prefill past this len
    max_engines: Optional[int] = None  # LRU cap on live engines (None = off)
    stage_slots: int = 0              # in-segment admission ring (0 = off)
    admission: str = "worstcase"      # page admission: worstcase|optimistic
    preempt_policy: str = "slack"     # pressure victim choice: slack|lru
    prefix_cache: bool = False        # page-granular prompt-prefix sharing
    prefix_evict: str = "lru"         # cached-page eviction: lru|fifo
    stream: bool = False              # per-segment partial outputs through
    #                                   ExecRequest.on_tokens (TTFT)


class EngineExecutor:
    """Real executor: worker jobs run on per-variant ``ServingEngine``s.

    ``arch_cfgs`` maps architecture name -> (reduced) ``ArchConfig``; pass
    a shared ``model_cache`` dict to reuse built params across executors
    (one per worker) in the same cluster.
    """

    def __init__(self, arch_cfgs: Dict[str, ArchConfig],
                 cfg: EngineExecutorConfig = EngineExecutorConfig(),
                 model_cache: Optional[Dict[str, Tuple[Any, Any]]] = None):
        self.arch_cfgs = dict(arch_cfgs)
        self.cfg = cfg
        self.engines: Dict[str, ServingEngine] = {}      # by variant name
        # bounded per-(variant, batch) history: refits stay O(obs_window)
        # per job and memory stays flat in a long-running cluster
        self.observations: Dict[str, Dict[int, Deque[float]]] = {}
        self.refits: Dict[str, int] = {}                 # refit count
        self.evictions = 0                               # LRU engine drops
        # per-run occupancy records (the executor's decision log): how
        # full the fused segments ran, and how many requests in-segment
        # admission packed into them — the data-plane side of the control
        # plane's decision accounting. Bounded like `observations` so a
        # long-running cluster's memory stays flat.
        self.occupancy_log: Deque[Dict[str, Any]] = \
            deque(maxlen=max(cfg.obs_window * 8, 256))
        self._models = model_cache if model_cache is not None else {}
        self._rid = itertools.count()
        # serializes run() (engines, observations, occupancy_log): the
        # wall-clock runtime's stepper thread and direct callers may race
        self._lock = threading.RLock()

    # ------------------------------------------------------------------
    def _model(self, arch: str):
        entry = self._models.get(arch)
        if entry is None:
            import jax
            from repro.models.model import build_model
            cfg = self.arch_cfgs[arch]
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(self.cfg.seed))
            entry = (model, params)
            self._models[arch] = entry
        return entry

    def _engine(self, variant: Variant) -> ServingEngine:
        eng = self.engines.pop(variant.name, None)
        if eng is None:
            if self.cfg.max_engines is not None:
                # LRU cap: multi-arch real clusters stay host-sized.
                # Engines are idle between run() calls, so eviction never
                # drops in-flight state; an evicted variant rebuilds
                # lazily here and re-warms before the measured window.
                while len(self.engines) >= max(self.cfg.max_engines, 1):
                    victim = next(iter(self.engines))
                    del self.engines[victim]
                    self.evictions += 1
            model, params = self._model(variant.arch)
            kwargs = {}
            # xLSTM has no attention KV to page and chunked prefill /
            # in-segment admission are engine-gated per family (the
            # engine clamps the knobs itself)
            if self.cfg.page_size is not None:
                kwargs.update(page_size=self.cfg.page_size,
                              n_pages=self.cfg.n_pages)
            eng = ServingEngine(
                model, params,
                max_batch=min(self.cfg.max_batch,
                              max(variant.profile.max_batch, 1)),
                max_len=self.cfg.max_len,
                decode_block=self.cfg.decode_block,
                min_bucket=self.cfg.min_bucket,
                chunk_threshold=self.cfg.chunk_threshold,
                stage_slots=self.cfg.stage_slots,
                admission=self.cfg.admission,
                preempt_policy=self.cfg.preempt_policy,
                prefix_cache=self.cfg.prefix_cache,
                prefix_evict=self.cfg.prefix_evict,
                stream=self.cfg.stream,
                **kwargs)
            eng.warmup(prompt_lens=[self.cfg.prompt_len])
        # dict order doubles as the LRU list: reinsert on every access
        self.engines[variant.name] = eng
        return eng

    # ------------------------------------------------------------------
    def _synthetic_prompt(self, vocab: int) -> np.ndarray:
        return (np.arange(self.cfg.prompt_len, dtype=np.int64)
                % vocab).astype(np.int32)

    _OCC_KEYS = ("busy_slot_steps", "bubble_slot_steps",
                 "inseg_admissions", "decode_dispatches",
                 "preemptions", "pressure_stalls",
                 "prefix_hits", "prefix_pages_reused", "cow_copies",
                 "evictions")

    def _make_requests(self, er: ExecRequest, vocab: int,
                       t0: float) -> List[Request]:
        """One engine Request per payload prompt (or synthetic stand-in)."""
        ers: List[Request] = []
        if er.prompts:
            for p in er.prompts:
                ers.append(Request(
                    rid=next(self._rid),
                    prompt=np.asarray(p, np.int32),
                    max_new_tokens=max(er.max_new_tokens, 1),
                    arrival=t0, slo=er.slo))
        else:
            for _ in range(max(er.n_inputs, 1)):
                ers.append(Request(
                    rid=next(self._rid),
                    prompt=self._synthetic_prompt(vocab),
                    max_new_tokens=self.cfg.max_new, arrival=t0,
                    slo=er.slo))
        return ers

    def _pump_stream(self, eng: ServingEngine,
                     sinks: Dict[int, Tuple[ExecRequest, int]]) -> int:
        """Forward freshly harvested partial outputs to their queries'
        ``on_tokens`` sinks (no-op on non-streaming engines). Returns the
        number of chunks delivered."""
        if not eng.stream:
            return 0
        n = 0
        for r, toks, t in eng.drain_partial_outputs():
            ent = sinks.get(id(r))
            if ent is not None:
                er, idx = ent
                if er.on_tokens is not None:
                    er.on_tokens(idx, toks, t)
                    n += 1
        return n

    def _record_occupancy(self, variant: Variant, batch: int, dt: float,
                          occ0: Dict[str, int],
                          eng: ServingEngine) -> None:
        # decision-log entry: per-run occupancy of the fused segments
        d = {k: eng.stats[k] - occ0[k] for k in occ0}
        total = d["busy_slot_steps"] + d["bubble_slot_steps"]
        segs = d["decode_dispatches"]
        self.occupancy_log.append({
            "variant": variant.name, "batch": int(batch),
            "service_s": dt, "segments": segs,
            "slot_busy_frac":
                d["busy_slot_steps"] / total if total else 0.0,
            "admissions_per_segment":
                d["inseg_admissions"] / segs if segs else 0.0,
            "bubble_slot_steps": d["bubble_slot_steps"],
            "preemptions": d["preemptions"],
            "pressure_stalls": d["pressure_stalls"],
            # prefix-cache counters (all zero with the cache off): the
            # hit rate here is what model selection / autoscaling can
            # later key on to co-locate shared-prefix traffic
            "prefix_hits": d["prefix_hits"],
            "prefix_pages_reused": d["prefix_pages_reused"],
            "cow_copies": d["cow_copies"],
            "evictions": d["evictions"],
        })

    @staticmethod
    def _deliver(er: ExecRequest, ers: List[Request]) -> None:
        """Hand a finished group's tokens and degradation report back."""
        if er.on_outputs is not None:
            er.on_outputs([np.asarray(r.tokens, np.int32) for r in ers])
        if er.on_report is not None:
            # degradation report back to the control plane: a query
            # whose requests were preempted (and recovered) completed
            # degraded — identical tokens, borrowed time
            npre = sum(r.preemptions for r in ers)
            er.on_report({"preemptions": npre, "degraded": npre > 0})

    def _observe(self, variant: Variant, n: int, dt: float) -> None:
        """Fold one synthetic-batch measurement into the t(b) fit."""
        obs = self.observations.setdefault(variant.name, {})
        obs.setdefault(n, deque(maxlen=self.cfg.obs_window)).append(dt)
        if prof.refit_profile(variant.profile, obs,
                              min_points=self.cfg.refit_min_points):
            self.refits[variant.name] = \
                self.refits.get(variant.name, 0) + 1

    def run(self, variant: Variant, batch: int,
            requests: Optional[List[ExecRequest]] = None) -> float:
        """Serve one batch for real — each ExecRequest's payload prompts
        (or synthetic stand-ins) become engine Requests; return the
        measured service time, hand generated tokens back through each
        request's ``on_outputs`` sink, and fold the measurement into the
        variant's profile. With ``cfg.stream`` set, partial outputs are
        forwarded to each request's ``on_tokens`` sink after every engine
        step (synchronously, in emission order)."""
        with self._lock:
            eng = self._engine(variant)
            vocab = self.arch_cfgs[variant.arch].vocab
            if not requests:
                requests = [ExecRequest(n_inputs=max(int(batch), 1))]
            # compile any new prompt buckets outside the measured window,
            # so a first-seen payload length doesn't bill XLA compile time
            # as service time
            real_lens = [len(p) for er in requests for p in er.prompts]
            if real_lens:
                eng.warmup(prompt_lens=real_lens)
            groups: List[Tuple[ExecRequest, List[Request]]] = []
            occ0 = {k: eng.stats[k] for k in self._OCC_KEYS}
            t0 = time.perf_counter()
            sinks: Dict[int, Tuple[ExecRequest, int]] = {}
            for er in requests:
                ers = self._make_requests(er, vocab, t0)
                for i, r in enumerate(ers):
                    eng.submit(r)
                    sinks[id(r)] = (er, i)
                groups.append((er, ers))
            while eng.busy:
                eng.step()
                self._pump_stream(eng, sinks)
            eng.drain_completions()
            dt = time.perf_counter() - t0
            self._record_occupancy(variant, batch, dt, occ0, eng)
            for er, ers in groups:
                self._deliver(er, ers)
            # only synthetic runs calibrate t(b): they share one fixed
            # (prompt_len, max_new) shape, so duration varies with batch
            # count alone. Payload runs have arbitrary prompt/decode shapes
            # and would corrupt the shared m/c fit that selection and
            # autoscaling plan with (same hazard JaxExecutor.measured keys
            # by prompt_len to avoid).
            if not any(er.prompts for er in requests):
                n = max(sum(len(ers) for _, ers in groups), 1)
                self._observe(variant, n, dt)
            return dt
