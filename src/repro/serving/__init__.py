from repro.serving.engine import (ContinuousEngine, JaxExecutor,  # noqa: F401
                                  Request, ServingEngine, WaveEngine,
                                  bucket_len)
from repro.serving.executor import (EngineExecutor,  # noqa: F401
                                    EngineExecutorConfig)
