from repro.serving.engine import (ContinuousEngine, JaxExecutor,  # noqa: F401
                                  Request, ServingEngine, WaveEngine,
                                  bucket_len)
