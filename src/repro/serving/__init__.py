from repro.serving.engine import JaxExecutor, Request, ServingEngine  # noqa: F401
