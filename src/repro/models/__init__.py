from repro.models.model import Model, build_model, make_batch  # noqa: F401
