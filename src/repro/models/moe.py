"""Mixture-of-Experts decoder (moonshot 64e/top-6, qwen3 128e/top-8).

Two FFN lowerings:

* ``moe_ffn_dense`` — scatter/gather dispatch on a single device (smoke tests,
  host execution). Capacity-bounded top-k with token dropping, faithful to
  GShard-style serving MoE.
* ``moe_ffn_ep`` — expert-parallel shard_map: local top-k + capacity dispatch,
  ``all_to_all`` over the ``model`` mesh axis to the expert owners, expert
  GEMM, reverse ``all_to_all``, weighted combine. This is the TPU-native
  lowering (token dim collective only, no dispatch-mask blowup).

Attention/embedding reuse the dense transformer blocks.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import kvcache as KV
from repro.models.transformer import (_maybe_remat, _stacked_attn_init,
                                      decode_positions)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init


def _moe_mlp_init(rng, n: int, cfg: ArchConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": L.dense_init(ks[0], (n, d, e), jnp.float32, in_axis=1),
        "w_gate": L.dense_init(ks[1], (n, e, d, f), dtype, in_axis=2),
        "w_up": L.dense_init(ks[2], (n, e, d, f), dtype, in_axis=2),
        "w_down": L.dense_init(ks[3], (n, e, f, d), dtype, in_axis=2),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": L.dense_init(k1, (n, d, fs), dtype, in_axis=1),
            "w_up": L.dense_init(k2, (n, d, fs), dtype, in_axis=1),
            "w_down": L.dense_init(k3, (n, fs, d), dtype, in_axis=1),
        }
    return p


def init_moe(cfg: ArchConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, ka, km, kh = jax.random.split(rng, 4)
    n = cfg.n_layers
    return {
        "embed": L.embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "layers": {
            "attn": _stacked_attn_init(ka, n, cfg, dtype),
            "moe": _moe_mlp_init(km, n, cfg, dtype),
            "ln1": jnp.zeros((n, cfg.d_model), dtype),
            "ln2": jnp.zeros((n, cfg.d_model), dtype),
        },
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "head": L.embed_init(kh, (cfg.vocab, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# routing helpers


def _route(x2d: jax.Array, router: jax.Array, cfg: ArchConfig):
    """x2d: (T, d). Returns (weights (T,k) f32, experts (T,k) i32)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _dispatch_indices(idx: jax.Array, cfg: ArchConfig, capacity: int):
    """idx: (T, k) expert ids. Returns (pos (T,k), keep (T,k) bool).

    Position of each assignment within its expert's capacity buffer,
    computed with a cumulative count in flattened (token-major) order —
    the GShard dispatch order.
    """
    T, k = idx.shape
    flat = idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat, cfg.n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                      # pre-count
    pos_of = jnp.sum(pos * onehot, axis=-1).reshape(T, k)
    keep = pos_of < capacity
    return pos_of, keep


def _expert_gemm(buf: jax.Array, wg, wu, wd) -> jax.Array:
    """buf: (E, C, d) tokens grouped per expert; per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


# ---------------------------------------------------------------------------
# single-device (dense scatter) lowering


def moe_ffn_dense(x: jax.Array, p: Params, cfg: ArchConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). Scatter-based dispatch; no collectives."""
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    T = B * S
    w, idx = _route(x2, p["router"], cfg)
    C = _capacity(T, cfg)
    pos, keep = _dispatch_indices(idx, cfg, C)

    k = cfg.top_k
    tok = jnp.repeat(jnp.arange(T), k)            # (T*k,)
    e_f = idx.reshape(T * k)
    p_f = jnp.clip(pos.reshape(T * k), 0, C - 1)
    keep_f = keep.reshape(T * k)

    buf = jnp.zeros((cfg.n_experts, C, d), x.dtype)
    updates = x2[tok] * keep_f[:, None].astype(x.dtype)
    buf = buf.at[e_f, p_f].add(updates)

    out_buf = _expert_gemm(buf, p["w_gate"], p["w_up"], p["w_down"])

    gathered = out_buf[e_f, p_f]                   # (T*k, d)
    w_f = (w.reshape(T * k) * keep_f).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(gathered * w_f[:, None])
    y = y.reshape(B, S, d)
    if "shared" in p:
        y = y + L.swiglu(x, p["shared"])
    return y


# ---------------------------------------------------------------------------
# expert-parallel shard_map lowering


def moe_ffn_ep(x: jax.Array, p: Params, cfg: ArchConfig, parallel) -> jax.Array:
    """Expert parallelism over the ``model`` axis via explicit all_to_all.

    Tokens enter sharded over BOTH the data axes (batch) and the model axis
    (sequence) — matching the sequence-parallel residual stream — so each
    device routes only B_l*S/M tokens and the dispatch buffers stay small.
    """
    mesh = parallel.mesh
    ep_axis = parallel.model_axis
    M = mesh.shape[ep_axis]
    seq_shardable = x.shape[1] % M == 0 and x.shape[1] > 1
    data_spec = P(parallel.data_axes, ep_axis if seq_shardable else None,
                  None)
    assert cfg.n_experts % M == 0, "n_experts must divide the model axis"
    e_local = cfg.n_experts // M

    def local_fn(x_l, router, wg, wu, wd):
        # x_l: (B_l, S, d); wg/wu/wd: (E_local, d, f); router: (d, E)
        Bl, S, d = x_l.shape
        T = Bl * S
        x2 = x_l.reshape(T, d)
        w, idx = _route(x2, router, cfg)
        C = _capacity(T, cfg)
        pos, keep = _dispatch_indices(idx, cfg, C)
        k = cfg.top_k
        tok = jnp.repeat(jnp.arange(T), k)
        e_f = idx.reshape(T * k)
        p_f = jnp.clip(pos.reshape(T * k), 0, C - 1)
        keep_f = keep.reshape(T * k)
        buf = jnp.zeros((cfg.n_experts, C, d), x_l.dtype)
        buf = buf.at[e_f, p_f].add(x2[tok] * keep_f[:, None].astype(x_l.dtype))
        # (E, C, d) -> (M, E_local, C, d) -> all_to_all over the EP axis
        buf = buf.reshape(M, e_local, C, d)
        buf = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)
        # (M, E_local, C, d): tokens from every source shard for my experts
        buf = buf.transpose(1, 0, 2, 3).reshape(e_local, M * C, d)
        out = _expert_gemm(buf, wg, wu, wd)
        # reverse: (E_local, M*C, d) -> (M, E_local, C, d) -> all_to_all back
        out = out.reshape(e_local, M, C, d).transpose(1, 0, 2, 3)
        out = lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)
        out = out.reshape(cfg.n_experts, C, d)
        gathered = out[e_f, p_f]
        w_f = (w.reshape(T * k) * keep_f).astype(x_l.dtype)
        y = jnp.zeros((T, d), x_l.dtype).at[tok].add(gathered * w_f[:, None])
        return y.reshape(Bl, S, d)

    from jax.experimental.shard_map import shard_map
    # spec P(ep_axis) shards dim0 (E) of the expert weights across the axis
    in_specs = (data_spec, P(), P(ep_axis), P(ep_axis), P(ep_axis))
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=in_specs,
                   out_specs=data_spec, check_rep=False)
    y = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if "shared" in p:
        # shared experts are dense; GSPMD handles them outside the shard_map
        y = y + L.swiglu(x, p["shared"])
    return y


def moe_ffn(x, p, cfg: ArchConfig, parallel=None) -> jax.Array:
    if parallel is not None and parallel.moe_impl == "ep":
        return moe_ffn_ep(x, p, cfg, parallel)
    return moe_ffn_dense(x, p, cfg)


# ---------------------------------------------------------------------------
# full model: forward / prefill / decode


def _moe_block(x, blk, cfg: ArchConfig, parallel, *, positions=None):
    h = L.rmsnorm(x, blk["ln1"])
    q, k, v = L.attn_qkv(h, blk["attn"])
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = L.attention_core(q, k, v, causal=True, impl=cfg.attention_impl)
    x = x + L.attn_out(o, blk["attn"])
    x = x + moe_ffn(L.rmsnorm(x, blk["ln2"]), blk["moe"], cfg, parallel)
    return L.constrain_residual(x), (k, v)


def forward_moe(cfg: ArchConfig, params: Params, tokens: jax.Array,
                parallel=None) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(tokens, params["embed"], dtype)

    def body(carry, blk):
        out, _ = _moe_block(carry, blk, cfg, parallel)
        return out, None

    x, _ = lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    x = L.rmsnorm(x, params["ln_f"])
    return L.lm_logits(x, params["head"])


def prefill_moe(cfg: ArchConfig, params: Params, tokens: jax.Array,
                parallel=None, length: Optional[jax.Array] = None):
    """``length``: optional (B,) valid prefix lengths for right-padded
    prompts (see ``prefill_dense``). NOTE: expert capacity is computed from
    the padded token count, so capacity-induced token drops can differ from
    an exact-length run under extreme router imbalance."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = L.embed_tokens(tokens, params["embed"], dtype)

    def body(carry, blk):
        out, (k, v) = _moe_block(carry, blk, cfg, parallel,
                                 positions=positions)
        return out, (k, v)

    x, (ks, vs) = lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(L.select_last(x, length), params["head"])
    return logits, {"k": ks, "v": vs}


def decode_moe(cfg: ArchConfig, params: Params, cache, token: jax.Array, pos,
               parallel=None):
    """``cache`` may carry a ``"bt"`` block table, in which case its k/v
    leaves are shared page pools (see ``repro.models.kvcache``)."""
    dtype = jnp.dtype(cfg.dtype)
    bt = cache.get("bt")
    x = L.embed_tokens(token, params["embed"], dtype)

    def body(carry, xs):
        blk, kc, vc = xs
        h = L.rmsnorm(carry, blk["ln1"])
        q, k, v = L.attn_qkv(h, blk["attn"])
        positions = decode_positions(pos, carry.shape[0])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if bt is None:
            kc, vc = KV.update_layer_cache(kc, vc, k, v, pos)
            o = L.attention_core(q, kc, vc, causal=False,
                                 kv_valid_len=pos + 1,
                                 impl=cfg.attention_impl)
        else:
            o, kc, vc = L.paged_update_attend(q, k, v, kc, vc, bt, pos,
                                              impl=cfg.attention_impl)
        out = carry + L.attn_out(o, blk["attn"])
        out = out + moe_ffn(L.rmsnorm(out, blk["ln2"]), blk["moe"], cfg,
                            parallel)
        return out, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(x, params["head"])
    out_cache = {"k": ks, "v": vs}
    if bt is not None:
        out_cache["bt"] = bt
    return logits, out_cache
