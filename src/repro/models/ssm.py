"""Mamba2 (SSD) blocks and the Zamba2 hybrid backbone.

The SSD layer follows the chunked algorithm of Mamba-2 [arXiv:2405.21060]:
intra-chunk contributions via a (Q, Q) decay-masked score matrix, inter-chunk
via a scan over per-chunk states. Decode is the O(1)-per-token recurrence on
the (B, H, N, P) state — this is what makes ``long_500k`` runnable.

Zamba2 [arXiv:2411.15242]: a stack of Mamba2 layers with ONE shared
transformer block (attention + SwiGLU, identical parameters) invoked every
``shared_attn_every`` layers. We structure it as scan-over-groups:
(shared_attn_every mamba layers, then the shared block), plus a mamba tail.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import kvcache as KV
from repro.models.transformer import (_maybe_remat, _stacked_attn_init,
                                      decode_positions)

Params = Dict[str, Any]

CONV_WIDTH = 4


def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads_mamba, head_dim_mamba, conv_channels)."""
    d_inner = 2 * cfg.d_model
    p = 64 if cfg.d_model >= 512 else 16
    h = d_inner // p
    conv_ch = d_inner + 2 * cfg.ssm_state
    return d_inner, h, p, conv_ch


# ---------------------------------------------------------------------------
# init


def _mamba_stack_init(rng, n: int, cfg: ArchConfig, dtype) -> Params:
    # projections kept SEPARATE (not packed) so each output dim shards
    # cleanly: w_z/w_x on d_inner (head-aligned), w_bc replicated (tiny),
    # w_dt on mamba heads. Depthwise convs split exactly the same way
    # (depthwise conv of a concat == concat of depthwise convs).
    d = cfg.d_model
    di, h, p_, ci = mamba_dims(cfg)
    n_state = cfg.ssm_state
    ks = jax.random.split(rng, 7)
    return {
        "w_z": L.dense_init(ks[0], (n, d, di), dtype, in_axis=1),
        "w_x": L.dense_init(ks[1], (n, d, di), dtype, in_axis=1),
        "w_bc": L.dense_init(ks[2], (n, d, 2 * n_state), dtype, in_axis=1),
        "w_dt": L.dense_init(ks[3], (n, d, h), jnp.float32, in_axis=1),
        "conv_x_w": L.dense_init(ks[4], (n, CONV_WIDTH, di), dtype,
                                 in_axis=1),
        "conv_x_b": jnp.zeros((n, di), dtype),
        "conv_bc_w": L.dense_init(ks[5], (n, CONV_WIDTH, 2 * n_state), dtype,
                                  in_axis=1),
        "conv_bc_b": jnp.zeros((n, 2 * n_state), dtype),
        "A_log": jnp.zeros((n, h), jnp.float32),        # A = -exp(A_log)
        "D": jnp.ones((n, h), jnp.float32),
        "dt_bias": jnp.zeros((n, h), jnp.float32),
        "norm": jnp.zeros((n, di), dtype),
        "out_proj": L.dense_init(ks[6], (n, di, d), dtype, in_axis=1),
    }


def init_zamba(cfg: ArchConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ksh, kh = jax.random.split(rng, 4)
    ka, km2 = jax.random.split(ksh)
    shared = {
        "attn": jax.tree.map(lambda a: a[0], _stacked_attn_init(ka, 1, cfg, dtype)),
        "mlp": {
            "w_gate": L.dense_init(jax.random.fold_in(km2, 0),
                                   (cfg.d_model, cfg.d_ff), dtype, in_axis=0),
            "w_up": L.dense_init(jax.random.fold_in(km2, 1),
                                 (cfg.d_model, cfg.d_ff), dtype, in_axis=0),
            "w_down": L.dense_init(jax.random.fold_in(km2, 2),
                                   (cfg.d_ff, cfg.d_model), dtype, in_axis=0),
        },
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    return {
        "embed": L.embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "mamba": _mamba_stack_init(km, cfg.n_layers, cfg, dtype),
        "shared": shared,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "head": L.embed_init(kh, (cfg.vocab, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# SSD core


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., Q) log-decay increments -> (..., Q, Q) masked cumulative sums
    M[i, j] = sum_{l in (j, i]} x_l for i >= j, -inf otherwise."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xdt: jax.Array, dA: jax.Array, B_: jax.Array, C_: jax.Array,
                chunk: int, h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    xdt: (b, l, h, p) inputs pre-scaled by dt; dA: (b, l, h) log decays (<=0);
    B_, C_: (b, l, n) shared across heads (n_groups=1).
    h0: optional initial state (b, h, n, p).
    Returns (y (b, l, h, p), final_state (b, h, n, p)).
    """
    b, slen, h, p = xdt.shape
    n = B_.shape[-1]
    assert slen % chunk == 0, (slen, chunk)
    nc = slen // chunk
    x_ = xdt.reshape(b, nc, chunk, h, p)
    dA_ = dA.reshape(b, nc, chunk, h).astype(jnp.float32)
    B2 = B_.reshape(b, nc, chunk, n)
    C2 = C_.reshape(b, nc, chunk, n)

    # --- intra-chunk: decay-masked attention-like contraction
    Lm = jnp.exp(_segsum(dA_.transpose(0, 3, 1, 2)))          # (b,h,nc,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", C2, B2,
                        preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("bhcij,bcij,bcjhp->bcihp",
                         Lm.astype(xdt.dtype),
                         scores.astype(xdt.dtype), x_)

    # --- per-chunk end states
    cs = jnp.cumsum(dA_, axis=2)                              # (b,nc,Q,h)
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)             # (b,nc,Q,h)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", B2,
                     decay_to_end.astype(xdt.dtype), x_)

    # --- inter-chunk scan
    total = jnp.exp(cs[:, :, -1, :]).astype(xdt.dtype)        # (b,nc,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), xdt.dtype)

    def body(S_prev, inp):
        tot, Sc = inp
        S_new = S_prev * tot[..., None, None] + Sc
        return S_new, S_prev

    S_final, S_prevs = lax.scan(
        body, h0, (total.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)                # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", C2,
                         jnp.exp(cs).astype(xdt.dtype),       # (b,nc,Q,h)
                         S_prevs)
    y = (y_intra + y_inter).reshape(b, slen, h, p)
    return y, S_final


def ssd_step(x1: jax.Array, dA1: jax.Array, B1: jax.Array, C1: jax.Array,
             state: jax.Array):
    """One-token recurrence. x1: (b,h,p) pre-scaled by dt; dA1: (b,h);
    B1, C1: (b,n); state: (b,h,n,p)."""
    decay = jnp.exp(dA1.astype(jnp.float32)).astype(x1.dtype)
    state = state * decay[..., None, None] + jnp.einsum("bn,bhp->bhnp", B1, x1)
    y = jnp.einsum("bn,bhnp->bhp", C1, state)
    return y, state


# ---------------------------------------------------------------------------
# Mamba2 block


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    return L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                     scale)


def _causal_conv_full(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C); w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return out + b[None, None, :]


def _conv_step(x1: jax.Array, conv_state: jax.Array, w: jax.Array,
               b: jax.Array):
    """x1: (B, C) one token; conv_state: (B, W-1, C)."""
    window = jnp.concatenate([conv_state, x1[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window, w) + b
    return out, window[:, 1:, :]


def mamba_block_full(x: jax.Array, p: Params, cfg: ArchConfig,
                     h0: Optional[jax.Array] = None,
                     mask: Optional[jax.Array] = None):
    """x: (B, L, d). Returns (y (B, L, d), final ssm_state (B, h, n, p)).

    ``mask``: optional (B, L) bool validity mask for right-padded prompts.
    Masked positions get dt = 0, i.e. decay exp(dt*A) = 1 and input dt*x = 0,
    which makes the SSD recurrence an exact identity there — the final state
    equals the state after the last valid token.
    """
    B, Lseq, d = x.shape
    di, h, pdim, ci = mamba_dims(cfg)
    n = cfg.ssm_state
    z = jnp.einsum("bld,dz->blz", x, p["w_z"])
    xs = jnp.einsum("bld,dz->blz", x, p["w_x"])
    bc = jnp.einsum("bld,dz->blz", x, p["w_bc"])
    dt = jnp.einsum("bld,dz->blz", x.astype(jnp.float32), p["w_dt"])
    xs = jax.nn.silu(_causal_conv_full(xs, p["conv_x_w"], p["conv_x_b"])
                     .astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(_causal_conv_full(bc, p["conv_bc_w"], p["conv_bc_b"])
                     .astype(jnp.float32)).astype(x.dtype)
    Bm, Cm = jnp.split(bc, [n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])                        # (B,L,h)
    if mask is not None:
        dt = dt * mask[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"])                                       # (h,)
    dA = dt * A                                                    # (B,L,h)
    xh = xs.reshape(B, Lseq, h, pdim)
    xdt = xh * dt[..., None].astype(x.dtype)
    y, state = ssd_chunked(xdt, dA, Bm, Cm, min(cfg.ssm_chunk, Lseq), h0)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, Lseq, di)
    y = _gated_rmsnorm(y, z, p["norm"])
    out = jnp.einsum("bld,dz->blz", y, p["out_proj"])
    return out, state


def mamba_block_step(x1: jax.Array, p: Params, cfg: ArchConfig,
                     ssm_state: jax.Array, conv_state: jax.Array):
    """x1: (B, 1, d) one token. Returns (y (B,1,d), (ssm_state, conv_state)).

    conv_state: (B, W-1, di + 2n) — the x and BC conv tails concatenated.
    """
    B = x1.shape[0]
    di, h, pdim, ci = mamba_dims(cfg)
    n = cfg.ssm_state
    x0 = x1[:, 0, :]
    z = jnp.einsum("bd,dz->bz", x0, p["w_z"])
    xs = jnp.einsum("bd,dz->bz", x0, p["w_x"])
    bc = jnp.einsum("bd,dz->bz", x0, p["w_bc"])
    dt = jnp.einsum("bd,dz->bz", x0.astype(jnp.float32), p["w_dt"])
    cs_x, cs_bc = conv_state[..., :di], conv_state[..., di:]
    xs, cs_x = _conv_step(xs, cs_x, p["conv_x_w"], p["conv_x_b"])
    bc, cs_bc = _conv_step(bc, cs_bc, p["conv_bc_w"], p["conv_bc_b"])
    conv_state = jnp.concatenate([cs_x, cs_bc], axis=-1)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x1.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x1.dtype)
    Bm, Cm = jnp.split(bc, [n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])                        # (B,h)
    A = -jnp.exp(p["A_log"])
    dA = dt * A
    xh = xs.reshape(B, h, pdim)
    y, ssm_state = ssd_step(xh * dt[..., None].astype(x1.dtype), dA, Bm, Cm,
                            ssm_state)
    y = y + xh * p["D"][None, :, None].astype(x1.dtype)
    y = y.reshape(B, di)
    y = _gated_rmsnorm(y[:, None, :], z[:, None, :], p["norm"])
    out = jnp.einsum("bld,dz->blz", y, p["out_proj"])
    return out, (ssm_state, conv_state)


# NOTE: mamba_block_full returns only the ssm state; the conv tail needed to
# continue decoding after a prefill is recomputed here (last W-1 conv inputs).
def mamba_conv_tail(x: jax.Array, p: Params, cfg: ArchConfig,
                    length: Optional[jax.Array] = None) -> jax.Array:
    """``length``: optional (B,) valid prefix lengths. The conv window must
    hold the last W-1 *valid* inputs, which for right-padded prompts sit at
    positions length-(W-1)..length-1 (zero rows where that underflows, the
    causal conv's implicit zero padding)."""
    W1 = CONV_WIDTH - 1
    if length is None:
        tail = x[:, -W1:, :]
        valid = None
    else:
        idx = length[:, None].astype(jnp.int32) - W1 + jnp.arange(W1)[None, :]
        tail = jnp.take_along_axis(x, jnp.clip(idx, 0)[..., None], axis=1)
        valid = (idx >= 0)[..., None]
    xs = jnp.einsum("bld,dz->blz", tail, p["w_x"])
    bc = jnp.einsum("bld,dz->blz", tail, p["w_bc"])
    out = jnp.concatenate([xs, bc], axis=-1)
    if valid is not None:
        out = jnp.where(valid, out, 0.0).astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Zamba2: grouped hybrid stack


def _zamba_groups(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_groups, n_tail): layers = n_groups*shared_attn_every + n_tail."""
    g = cfg.n_layers // cfg.shared_attn_every
    return g, cfg.n_layers - g * cfg.shared_attn_every


def _shared_block(x, shared, cfg: ArchConfig, positions=None):
    h = L.rmsnorm(x, shared["ln1"])
    q, k, v = L.attn_qkv(h, shared["attn"])
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = L.attention_core(q, k, v, causal=True, impl=cfg.attention_impl)
    x = x + L.attn_out(o, shared["attn"])
    x = x + L.swiglu(L.rmsnorm(x, shared["ln2"]), shared["mlp"])
    return x, (k, v)


def _split_mamba_stack(params: Params, cfg: ArchConfig):
    g, tail = _zamba_groups(cfg)
    per = cfg.shared_attn_every
    grouped = jax.tree.map(
        lambda a: a[: g * per].reshape((g, per) + a.shape[1:]), params["mamba"])
    tail_p = jax.tree.map(lambda a: a[g * per:], params["mamba"])
    return grouped, tail_p, g, tail


def forward_zamba(cfg: ArchConfig, params: Params, tokens: jax.Array):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(tokens, params["embed"], dtype)
    grouped, tail_p, g, tail = _split_mamba_stack(params, cfg)
    shared = params["shared"]

    def group_body(carry, blks):
        def inner(c, blk):
            out, _ = mamba_block_full(c, blk, cfg)
            return L.constrain_residual(c + out), None
        carry, _ = lax.scan(_maybe_remat(inner, cfg), carry, blks)
        carry, _ = _shared_block(carry, shared, cfg)
        return carry, None

    x, _ = lax.scan(_maybe_remat(group_body, cfg), x, grouped)

    def tail_body(c, blk):
        out, _ = mamba_block_full(c, blk, cfg)
        return L.constrain_residual(c + out), None
    x, _ = lax.scan(_maybe_remat(tail_body, cfg), x, tail_p)

    x = L.rmsnorm(x, params["ln_f"])
    return L.lm_logits(x, params["head"])


def prefill_zamba(cfg: ArchConfig, params: Params, tokens: jax.Array,
                  length: Optional[jax.Array] = None):
    """``length``: optional (B,) valid prefix lengths for right-padded
    prompts. Mamba layers mask dt at padded positions (identity recurrence)
    and gather the conv tail at the last valid inputs; the shared attention
    block is causal, so its valid positions ignore right padding."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    mask = None if length is None else \
        jnp.arange(S)[None, :] < length[:, None]
    x = L.embed_tokens(tokens, params["embed"], dtype)
    grouped, tail_p, g, tail = _split_mamba_stack(params, cfg)
    shared = params["shared"]

    def group_body(carry, blks):
        def inner(c, blk):
            out, state = mamba_block_full(c, blk, cfg, mask=mask)
            return L.constrain_residual(c + out), \
                (state, mamba_conv_tail(c, blk, cfg, length))
        carry, (states, convs) = lax.scan(_maybe_remat(inner, cfg), carry, blks)
        carry, (k, v) = _shared_block(carry, shared, cfg, positions)
        return carry, (states, convs, k, v)

    x, (g_states, g_convs, ks, vs) = lax.scan(_maybe_remat(group_body, cfg),
                                              x, grouped)

    def tail_body(c, blk):
        out, state = mamba_block_full(c, blk, cfg, mask=mask)
        return c + out, (state, mamba_conv_tail(c, blk, cfg, length))
    x, (t_states, t_convs) = lax.scan(tail_body, x, tail_p)

    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(L.select_last(x, length), params["head"])
    di, h, pdim, ci = mamba_dims(cfg)
    cache = {
        "ssm": jnp.concatenate(
            [g_states.reshape((-1,) + g_states.shape[2:]), t_states], axis=0),
        "conv": jnp.concatenate(
            [g_convs.reshape((-1,) + g_convs.shape[2:]), t_convs], axis=0),
        "k": ks, "v": vs,  # (g, B, S, K, D) shared-block KV per invocation
    }
    return logits, cache


def decode_zamba(cfg: ArchConfig, params: Params, cache, token: jax.Array,
                 pos):
    """``cache`` may carry a ``"bt"`` block table: the shared-block k/v
    leaves are then (g, n_pages, page_size, K, D) shared pools while the
    O(1) ssm/conv states stay batch-indexed (paging is attention-only)."""
    dtype = jnp.dtype(cfg.dtype)
    B = token.shape[0]
    bt = cache.get("bt")
    x = L.embed_tokens(token, params["embed"], dtype)
    grouped, tail_p, g, tail = _split_mamba_stack(params, cfg)
    shared = params["shared"]
    per = cfg.shared_attn_every

    ssm = cache["ssm"]
    conv = cache["conv"]
    g_ssm = ssm[: g * per].reshape((g, per) + ssm.shape[1:])
    t_ssm = ssm[g * per:]
    g_conv = conv[: g * per].reshape((g, per) + conv.shape[1:])
    t_conv = conv[g * per:]

    def shared_step(c, kc, vc):
        h = L.rmsnorm(c, shared["ln1"])
        q, k, v = L.attn_qkv(h, shared["attn"])
        positions = decode_positions(pos, B)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if bt is None:
            kc, vc = KV.update_layer_cache(kc, vc, k, v, pos)
            o = L.attention_core(q, kc, vc, causal=False,
                                 kv_valid_len=pos + 1,
                                 impl=cfg.attention_impl)
        else:
            o, kc, vc = L.paged_update_attend(q, k, v, kc, vc, bt, pos,
                                              impl=cfg.attention_impl)
        c = c + L.attn_out(o, shared["attn"])
        c = c + L.swiglu(L.rmsnorm(c, shared["ln2"]), shared["mlp"])
        return c, kc, vc

    def group_body(carry, xs):
        blks, s_states, c_states, kc, vc = xs

        def inner(c, layer_xs):
            blk, st, cv = layer_xs
            out, (st, cv) = mamba_block_step(c, blk, cfg, st, cv)
            return c + out, (st, cv)

        carry, (s_states, c_states) = lax.scan(
            inner, carry, (blks, s_states, c_states))
        carry, kc, vc = shared_step(carry, kc, vc)
        return carry, (s_states, c_states, kc, vc)

    x, (g_ssm, g_conv, ks, vs) = lax.scan(
        group_body, x, (grouped, g_ssm, g_conv, cache["k"], cache["v"]))

    def tail_body(c, xs):
        blk, st, cv = xs
        out, (st, cv) = mamba_block_step(c, blk, cfg, st, cv)
        return c + out, (st, cv)
    x, (t_ssm, t_conv) = lax.scan(tail_body, x, (tail_p, t_ssm, t_conv))

    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(x, params["head"])
    cache = {
        "ssm": jnp.concatenate(
            [g_ssm.reshape((-1,) + g_ssm.shape[2:]), t_ssm], axis=0),
        "conv": jnp.concatenate(
            [g_conv.reshape((-1,) + g_conv.shape[2:]), t_conv], axis=0),
        "k": ks, "v": vs,
    }
    if bt is not None:
        cache["bt"] = bt
    return logits, cache


def zamba_cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    """ShapeDtypeStructs for the zamba decode cache."""
    di, h, pdim, ci = mamba_dims(cfg)
    g, tail = _zamba_groups(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ssm": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, h, cfg.ssm_state, pdim), dtype),
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, CONV_WIDTH - 1, ci), dtype),
        "k": jax.ShapeDtypeStruct(
            (g, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jax.ShapeDtypeStruct(
            (g, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
