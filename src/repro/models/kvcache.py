"""KV-cache and recurrent-state containers.

Caches are plain pytrees (dicts of arrays) so they cross pjit/shard_map
boundaries and checkpoint naturally. Attention caches are laid out
(L, B, S_max, K, D) — layer-major so the per-layer scan can consume them as
scan xs and emit updated slices as ys.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Cache = Dict[str, Any]


def alloc_attn_cache(n_layers: int, batch: int, max_len: int, n_kv: int,
                     head_dim: int, dtype) -> Cache:
    shape = (n_layers, batch, max_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def update_layer_cache(k_cache: jax.Array, v_cache: jax.Array,
                       k_new: jax.Array, v_new: jax.Array,
                       pos: Any) -> Tuple[jax.Array, jax.Array]:
    """Write (B, S_new, K, D) at position ``pos`` of a (B, S_max, K, D) buffer.

    ``pos`` is either a shared scalar position (run-to-completion waves, all
    sequences in lockstep) or a (B,) vector of per-sequence positions
    (continuous batching: every batch slot is at its own decode offset). The
    vector form lowers to a per-row scatter via vmap.
    """
    if jnp.ndim(pos) == 0:
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
        return k_cache, v_cache
    write = jax.vmap(
        lambda c, n, p: lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    k_cache = write(k_cache, k_new.astype(k_cache.dtype), pos)
    v_cache = write(v_cache, v_new.astype(v_cache.dtype), pos)
    return k_cache, v_cache
