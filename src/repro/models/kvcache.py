"""KV-cache and recurrent-state containers.

Caches are plain pytrees (dicts of arrays) so they cross pjit/shard_map
boundaries and checkpoint naturally. Attention caches come in two layouts:

* contiguous — (L, B, S_max, K, D), layer-major so the per-layer scan can
  consume them as scan xs and emit updated slices as ys. Every batch slot
  owns a private ``S_max`` run of positions.
* paged — a shared page pool (L, n_pages, page_size, K, D) plus a per-slot
  block table (B, S_max // page_size) of page indices. Logical position
  ``p`` of slot ``b`` lives at ``pool[bt[b, p // page_size], p % page_size]``.
  Unallocated block-table entries carry the sentinel ``n_pages`` (one past
  the pool): writes routed there are dropped (scatter ``mode="drop"``) and
  reads clamp to the last page, whose values are always masked off by the
  caller's ``kv_valid_len``. The pool is shared across batch slots, so slot
  count is no longer bound by worst-case context length — the serving
  engine's page allocator hands pages to slots as their ``pos`` grows.

Page reclaim is safe at any host boundary, including *mid-stream preempts*
(optimistic admission frees a live victim's pages): pointing the victim's
block-table row back at the sentinel detaches it from the pool without
touching neighbors, and a reclaimed page can be handed to another slot
immediately — its stale contents sit behind the new holder's write
frontier, and every position is rewritten by the new holder before any
masked read (``kv_valid_len``) can include it. This is the same argument
that makes slot reuse exact, applied page-at-a-time.

Recurrent families' O(1) states (SSM, conv tails, xLSTM cells) have no
sequence axis and stay batch-indexed in either layout.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Cache = Dict[str, Any]


def alloc_attn_cache(n_layers: int, batch: int, max_len: int, n_kv: int,
                     head_dim: int, dtype) -> Cache:
    shape = (n_layers, batch, max_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def alloc_paged_attn_cache(n_layers: int, n_pages: int, page_size: int,
                           n_kv: int, head_dim: int, dtype) -> Cache:
    """Shared page pool: (L, n_pages, page_size, K, D) per leaf."""
    shape = (n_layers, n_pages, page_size, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def update_layer_cache(k_cache: jax.Array, v_cache: jax.Array,
                       k_new: jax.Array, v_new: jax.Array,
                       pos: Any) -> Tuple[jax.Array, jax.Array]:
    """Write (B, S_new, K, D) at position ``pos`` of a (B, S_max, K, D) buffer.

    ``pos`` is either a shared scalar position (run-to-completion waves, all
    sequences in lockstep) or a (B,) vector of per-sequence positions
    (continuous batching: every batch slot is at its own decode offset). The
    vector form lowers to a per-row scatter via vmap.
    """
    if jnp.ndim(pos) == 0:
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
        return k_cache, v_cache
    write = jax.vmap(
        lambda c, n, p: lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    k_cache = write(k_cache, k_new.astype(k_cache.dtype), pos)
    v_cache = write(v_cache, v_new.astype(v_cache.dtype), pos)
    return k_cache, v_cache


def page_coords(block_table: jax.Array, pos: Any,
                page_size: int) -> Tuple[jax.Array, jax.Array]:
    """(page, offset) of logical position ``pos`` per slot.

    block_table: (B, P) page indices; ``pos`` a scalar or (B,) vector.
    Slots whose block-table entry is the sentinel (== n_pages) keep it, so
    downstream scatters drop the write.
    """
    B = block_table.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    blk = jnp.clip(pos // page_size, 0, block_table.shape[1] - 1)
    page = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    return page, pos % page_size


def paged_update_layer_cache(k_pool: jax.Array, v_pool: jax.Array,
                             k_new: jax.Array, v_new: jax.Array,
                             block_table: jax.Array,
                             pos: Any) -> Tuple[jax.Array, jax.Array]:
    """Write one token's (B, 1, K, D) k/v at logical ``pos`` of each slot
    into a shared (n_pages, page_size, K, D) pool through the block table.

    The engine's page allocator guarantees no page is referenced by two
    live slots, so the per-slot scatters never collide; sentinel pages
    (freed or never-allocated slots) drop the write.
    """
    page, off = page_coords(block_table, pos, k_pool.shape[1])
    k_pool = k_pool.at[page, off].set(k_new[:, 0].astype(k_pool.dtype),
                                      mode="drop")
    v_pool = v_pool.at[page, off].set(v_new[:, 0].astype(v_pool.dtype),
                                      mode="drop")
    return k_pool, v_pool


def sentinel_block_table(n_rows: int, pages_per_slot: int,
                         n_pages: int) -> np.ndarray:
    """All-sentinel block table rows (host-side, int32): every entry is
    ``n_pages`` — one past the pool — so writes drop and masked reads
    clamp. The serving engine starts every slot here and returns a slot's
    row here whenever its pages are reclaimed: at sequence finish *and* at
    preemption, where the request is parked and its pages handed out
    while it waits (safe per the module docstring's rewrite-before-read
    argument)."""
    return np.full((n_rows, pages_per_slot), n_pages, np.int32)


def reset_slot_rows(leaf: jax.Array, batch_axis: int, take: jax.Array,
                    empty_row: jax.Array) -> jax.Array:
    """Replace the batch rows of a slot-indexed state leaf selected by
    ``take`` (B,) bool with ``empty_row`` (the leaf's 1-row empty state,
    batch axis leading).

    This is the in-segment slot-reset primitive: when the serving engine's
    fused decode loop pulls a staged request into a freed slot, the slot's
    O(1) recurrent-state rows (SSM/conv/xLSTM cells) must restart from the
    family's empty state *inside* the traced loop body. Attention KV leaves
    need no reset — a position is always rewritten by its new occupant
    before any masked read can include it — so callers skip leaves that
    carry a sequence axis.
    """
    arr = jnp.moveaxis(leaf, batch_axis, 0)
    cond = take.reshape((-1,) + (1,) * (arr.ndim - 1))
    arr = jnp.where(cond, empty_row.astype(arr.dtype), arr)
    return jnp.moveaxis(arr, 0, batch_axis)


def gather_pool_view(pool: jax.Array, block_table: jax.Array,
                     batch_axis: int, seq_axis: int) -> jax.Array:
    """Materialize a contiguous-layout leaf from a paged pool leaf.

    ``pool`` is a pool-shaped leaf whose page axis sits at ``seq_axis - 1``
    and whose in-page offset axis at ``seq_axis`` (the engine's
    ``_pool_shape`` puts them where the contiguous leaf's sequence axis
    was, after dropping the batch axis). The result has the batch axis at
    ``batch_axis`` and a ``P * page_size`` sequence axis at ``seq_axis`` —
    exactly the contiguous-layout leaf shape, so a decode loop can run the
    *contiguous* update/attend program over it. Sentinel block-table
    entries clamp to the last page; their positions always sit at or past
    the caller's ``kv_valid_len`` and mask to exact zeros downstream.
    """
    pa = seq_axis - 1
    pool2 = jnp.moveaxis(pool, (pa, pa + 1), (0, 1))     # (n_pages, ps, ..)
    bt = jnp.clip(block_table, 0, pool.shape[pa] - 1)
    pages = jnp.take(pool2, bt, axis=0)                  # (B, P, ps, ..)
    B, P = block_table.shape
    view = pages.reshape((B, P * pool.shape[pa + 1]) + pool2.shape[2:])
    return jnp.moveaxis(view, (0, 1), (batch_axis, seq_axis))


def scatter_pool_view(pool: jax.Array, view: jax.Array,
                      block_table: jax.Array, batch_axis: int,
                      seq_axis: int, start: jax.Array,
                      stop: jax.Array) -> jax.Array:
    """Write positions ``[start[b], stop[b])`` of each slot's contiguous
    view back into the paged pool through the block table.

    The inverse of :func:`gather_pool_view`, restricted to the span a
    decode segment actually wrote: the fused loop decodes on the gathered
    view and flushes only ``[segment entry pos, exit pos)`` per slot, so
    pages the slot no longer owns (released mid-segment) and pages it
    shares read-only with other slots (prefix cache) are never touched.
    Positions routed to sentinel entries drop, as with the per-step
    scatter path.
    """
    pa = seq_axis - 1
    n_pages, ps = pool.shape[pa], pool.shape[pa + 1]
    v2 = jnp.moveaxis(view, (batch_axis, seq_axis), (0, 1))   # (B, S, ..)
    B, S = v2.shape[:2]
    poss = jnp.arange(S, dtype=jnp.int32)[None, :]            # (1, S)
    in_range = (start[:, None] <= poss) & (poss < stop[:, None])
    blk = jnp.clip(poss // ps, 0, block_table.shape[1] - 1)
    page = jnp.take_along_axis(block_table,
                               jnp.broadcast_to(blk, (B, S)), axis=1)
    page = jnp.where(in_range, page, n_pages)                 # drop rest
    off = jnp.broadcast_to(poss % ps, (B, S))
    pool2 = jnp.moveaxis(pool, (pa, pa + 1), (0, 1))
    pool2 = pool2.at[page, off].set(v2.astype(pool2.dtype), mode="drop")
    return jnp.moveaxis(pool2, (0, 1), (pa, pa + 1))


def copy_pool_page(pool: jax.Array, src: jax.Array, dst: jax.Array,
                   seq_axis: int) -> jax.Array:
    """Copy one physical page of a pool leaf (``src`` -> ``dst``), page
    axis at ``seq_axis - 1``: the device half of copy-on-write, run when a
    cache-hit admission must rewrite a position inside a shared page."""
    pa = seq_axis - 1
    pool2 = jnp.moveaxis(pool, pa, 0)
    row = lax.dynamic_index_in_dim(pool2, src, axis=0, keepdims=False)
    pool2 = pool2.at[dst].set(row, mode="drop")
    return jnp.moveaxis(pool2, 0, pa)


def gather_block_kv(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize each slot's logical KV view from the shared pool.

    pool: (n_pages, page_size, K, D); block_table: (B, P).
    Returns (B, P * page_size, K, D) — the same shape the contiguous layout
    attends over (P * page_size == S_max), so the attention computation is
    unchanged downstream. Sentinel entries clamp to the last page; their
    positions are always >= the caller's ``kv_valid_len`` and mask out.
    """
    B, P = block_table.shape
    ps = pool.shape[1]
    pages = jnp.take(pool, block_table, axis=0, mode="clip")  # (B,P,ps,K,D)
    return pages.reshape((B, P * ps) + pool.shape[2:])
