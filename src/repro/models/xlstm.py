"""xLSTM backbone [arXiv:2405.04517]: chunkwise-parallel mLSTM blocks with one
sequential sLSTM block every ``slstm_every`` layers.

mLSTM: matrix memory C (dk x dv per head) with exponential input gating and a
log-sigmoid forget gate; the chunkwise form stabilizes the exponentials with a
running max (carried across chunks), mirroring the recurrent stabilizer m_t of
the paper. A recurrent ``mlstm_recurrent`` oracle is kept for property tests.

sLSTM: scalar memory with hidden-state-dependent (block-diagonal per head)
recurrence — inherently sequential, computed with lax.scan over time.

Both states are O(1) in sequence length, so decode at 524k context is a
fixed-size state update (the sub-quadratic property gating ``long_500k``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.transformer import _maybe_remat

Params = Dict[str, Any]


def xlstm_groups(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_groups, m_per_group): layers = n_groups * (m_per_group + 1)."""
    per = cfg.slstm_every
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1


# ---------------------------------------------------------------------------
# init


def _mlstm_stack_init(rng, n: int, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(rng, 6)
    return {
        "w_up": L.dense_init(ks[0], (n, d, 2 * d), dtype, in_axis=1),
        "wq": L.dense_init(ks[1], (n, d, d), dtype, in_axis=1),
        "wk": L.dense_init(ks[2], (n, d, d), dtype, in_axis=1),
        "wv": L.dense_init(ks[3], (n, d, d), dtype, in_axis=1),
        "w_gate": L.dense_init(ks[4], (n, d, 2 * H), jnp.float32, in_axis=1),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((n, H), jnp.float32),          # input gate bias
             3.0 * jnp.ones((n, H), jnp.float32)],    # forget gate bias
            axis=-1),
        "w_down": L.dense_init(ks[5], (n, d, d), dtype, in_axis=1),
        "ln": jnp.zeros((n, d), dtype),
    }


def _slstm_stack_init(rng, n: int, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    f_ff = max(128, int(d * 4 / 3) // 64 * 64)
    ks = jax.random.split(rng, 7)
    return {
        "w_in": L.dense_init(ks[0], (n, d, 4 * d), dtype, in_axis=1),
        # recurrent block-diagonal weights, one (hd, hd) block per head/gate
        "r": L.dense_init(ks[1], (n, 4, H, hd, hd), jnp.float32, in_axis=-2),
        "bias": jnp.concatenate(
            [jnp.zeros((n, 2 * d), jnp.float32),
             3.0 * jnp.ones((n, d), jnp.float32),     # forget bias
             jnp.zeros((n, d), jnp.float32)], axis=-1),
        "ln": jnp.zeros((n, d), dtype),
        "ln2": jnp.zeros((n, d), dtype),
        "ffn": {
            "w_gate": L.dense_init(ks[2], (n, d, f_ff), dtype, in_axis=1),
            "w_up": L.dense_init(ks[3], (n, d, f_ff), dtype, in_axis=1),
            "w_down": L.dense_init(ks[4], (n, f_ff, d), dtype, in_axis=1),
        },
    }


def init_xlstm(cfg: ArchConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    g, m_per = xlstm_groups(cfg)
    ke, km, ksl, kh = jax.random.split(rng, 4)
    return {
        "embed": L.embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "mlstm": _mlstm_stack_init(km, g * m_per, cfg, dtype),
        "slstm": _slstm_stack_init(ksl, g, cfg, dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "head": L.embed_init(kh, (cfg.vocab, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel form


def mlstm_chunked(q, k, v, log_i, log_f, chunk: int, state=None):
    """q,k,v: (B, S, H, D); log_i/log_f: (B, S, H) f32.

    Returns (h (B,S,H,D), state=(C_hat (B,H,D,D), n_hat (B,H,D), m (B,H))).
    Stabilized: true C_t = exp(m_t) * C_hat_t.
    """
    B, S, H, D = q.shape
    assert S % chunk == 0
    nc = S // chunk
    scale = D ** -0.5
    qc = q.reshape(B, nc, chunk, H, D)
    kc = k.reshape(B, nc, chunk, H, D) * scale
    vc = v.reshape(B, nc, chunk, H, D)
    li = log_i.reshape(B, nc, chunk, H).astype(jnp.float32)
    lf = log_f.reshape(B, nc, chunk, H).astype(jnp.float32)

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_body(carry, inp):
        # Derivation: with csf_t the inclusive in-chunk cumsum of log_f and
        # a_j = log_i_j - csf_j, the true state satisfies
        #   C_t = exp(csf_t) [ sum_{j<=t} exp(a_j) k_j v_j^T + exp(m0) C_hat0 ]
        # and the recurrent stabilizer is m_t = csf_t + mt~ with
        #   mt~ = max(m0, cummax_{j<=t} a_j)     (m0 = carried FULL m).
        # All hat-quantities below are true values divided by exp(m_t).
        C_hat, n_hat, m_prev = carry
        qj, kj, vj, lij, lfj = inp      # (B, Q, H, D) / (B, Q, H)
        csf = jnp.cumsum(lfj, axis=1)                       # (B,Q,H) inclusive
        a = lij - csf                                       # (B,Q,H)
        run_amax = lax.cummax(a, axis=1)
        m_loc = jnp.maximum(run_amax, m_prev[:, None, :])   # mt~ (B,Q,H)
        # intra-chunk decay-scaled scores
        dmat = jnp.exp(a[:, None, :, :] - m_loc[:, :, None, :])  # (B,Qi,Qj,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(mask[None, :, :, None], dmat, 0.0)
        scores = jnp.einsum("bihd,bjhd->bijh", qj, kj,
                            preferred_element_type=jnp.float32)
        w = scores * dmat                                   # (B,Qi,Qj,H)
        num_intra = jnp.einsum("bijh,bjhd->bihd", w, vj.astype(jnp.float32))
        # denominator uses k (not v): n.q = sum_j weight_j (k_j.q_t)
        den_intra = jnp.sum(w, axis=2)                      # (B,Qi,H)
        # inter-chunk contribution
        inter_w = jnp.exp(m_prev[:, None, :] - m_loc)       # (B,Q,H)
        qf = qj.astype(jnp.float32)
        num_inter = jnp.einsum("bihd,bhde->bihe", qf, C_hat) * inter_w[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qf, n_hat) * inter_w
        num = num_intra + num_inter
        den = den_intra + den_inter
        # h = C q / max(|n.q|, 1) in true space == hat-space with exp(-m_full)
        m_full = csf + m_loc
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_full))
        h = num / denom[..., None]
        # --- end-of-chunk state update (hat-space w.r.t. m_tilde, then carry
        # the FULL m = m_tilde + csf_total so the next chunk is consistent)
        m_tilde = jnp.maximum(run_amax[:, -1, :], m_prev)
        wght = jnp.exp(a - m_tilde[:, None, :])             # (B,Q,H)
        kf = kj.astype(jnp.float32)
        C_new = (C_hat * jnp.exp(m_prev - m_tilde)[..., None, None]
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", wght, kf,
                              vj.astype(jnp.float32)))
        n_new = (n_hat * jnp.exp(m_prev - m_tilde)[..., None]
                 + jnp.einsum("bjh,bjhd->bhd", wght, kf))
        m_carry = m_tilde + csf[:, -1, :]
        return (C_new, n_new, m_carry), h.astype(q.dtype)

    (Cf, nf, mf), hs = lax.scan(
        chunk_body, (C0, n0, m0),
        (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4), li.transpose(1, 0, 2, 3),
         lf.transpose(1, 0, 2, 3)))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    return h, (Cf, nf, mf)


def mlstm_recurrent(q, k, v, log_i, log_f, state=None):
    """Step-by-step oracle (and decode path). Same shapes as mlstm_chunked."""
    B, S, H, D = q.shape
    scale = D ** -0.5
    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
        state = (C0, n0, m0)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lit, lft = inp
        qt = qt.astype(jnp.float32)
        kt = kt.astype(jnp.float32) * scale
        vt = vt.astype(jnp.float32)
        m_new = jnp.maximum(lft + m, lit)                   # (B,H)
        fw = jnp.exp(lft + m - m_new)
        iw = jnp.exp(lit - m_new)
        C = C * fw[..., None, None] + iw[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = n * fw[..., None] + iw[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        h = num / denom[..., None]
        return (C, n, m_new), h

    (Cf, nf, mf), hs = lax.scan(
        step, state,
        (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3),
         log_i.astype(jnp.float32).transpose(1, 0, 2),
         log_f.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2, 3).astype(q.dtype), (Cf, nf, mf)


def _mlstm_gates(xm, blk):
    H = blk["gate_bias"].shape[-1] // 2
    raw = jnp.einsum("bsd,dg->bsg", xm.astype(jnp.float32), blk["w_gate"])
    raw = raw + blk["gate_bias"][None, None, :]
    log_i, f_raw = raw[..., :H], raw[..., H:]
    log_f = jax.nn.log_sigmoid(f_raw)
    return log_i, log_f


def mlstm_block(x, blk, cfg: ArchConfig, state=None, mode="chunked",
                mask=None):
    """x: (B, S, d). Returns (y, new_state).

    ``mask``: optional (B, S) bool validity mask for right-padded prompts.
    Masked positions get log_i = -1e30 (no input) and log_f = 0 (keep), an
    exact identity on the (C, n, m) state once at least one valid token has
    been seen — guaranteed for right padding.
    """
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    h_in = L.rmsnorm(x, blk["ln"])
    up = jnp.einsum("bsd,dz->bsz", h_in, blk["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsd,de->bse", xm, blk["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", xm, blk["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", xm, blk["wv"]).reshape(B, S, H, hd)
    log_i, log_f = _mlstm_gates(xm, blk)
    if mask is not None:
        log_i = jnp.where(mask[..., None], log_i, -1e30)
        log_f = jnp.where(mask[..., None], log_f, 0.0)
    if mode == "chunked":
        h, new_state = mlstm_chunked(q, k, v, log_i, log_f,
                                     min(cfg.ssm_chunk, S), state)
    else:
        h, new_state = mlstm_recurrent(q, k, v, log_i, log_f, state)
    h = h.reshape(B, S, d) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsd,de->bse", h, blk["w_down"])
    return y, new_state


# ---------------------------------------------------------------------------
# sLSTM


def slstm_scan(x_gates, r, bias, H: int, state=None, mask=None):
    """x_gates: (B, S, 4d) pre-activations (z,i,f,o order, each d wide).

    r: (4, H, hd, hd) recurrent block-diag weights. Returns (h (B,S,d), state).
    ``mask``: optional (B, S) validity mask; the full (h, c, n, m) state is
    frozen at masked steps (the hidden h feeds the recurrence, so gate
    masking alone is not enough — the carry itself must be held).
    """
    B, S, G4 = x_gates.shape
    d = G4 // 4
    hd = d // H
    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        state = (zeros, zeros, zeros + 1e-6, jnp.full((B, d), -1e30))
    if mask is None:
        mask = jnp.ones((B, S), bool)

    def step(carry, inp):
        xt, keep = inp
        h_prev, c_prev, n_prev, m_prev = carry
        hp = h_prev.reshape(B, H, hd)
        rec = jnp.einsum("bhd,ghde->bghe", hp, r).reshape(B, 4 * d)
        pre = xt.astype(jnp.float32) + bias + rec
        z_, i_, f_, o_ = jnp.split(pre, 4, axis=-1)
        z_ = jnp.tanh(z_)
        o_ = jax.nn.sigmoid(o_)
        log_f = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(log_f + m_prev, i_)
        fw = jnp.exp(log_f + m_prev - m_new)
        iw = jnp.exp(i_ - m_new)
        c = fw * c_prev + iw * z_
        n = fw * n_prev + iw
        h = o_ * c / jnp.maximum(n, 1e-6)
        kb = keep[:, None]
        new = (jnp.where(kb, h, h_prev), jnp.where(kb, c, c_prev),
               jnp.where(kb, n, n_prev), jnp.where(kb, m_new, m_prev))
        return new, h

    (hf, cf, nf, mf), hs = lax.scan(
        step, state, (x_gates.transpose(1, 0, 2), mask.transpose(1, 0)))
    return hs.transpose(1, 0, 2), (hf, cf, nf, mf)


def slstm_block(x, blk, cfg: ArchConfig, state=None, mask=None):
    """x: (B, S, d). Returns (y, new_state)."""
    B, S, d = x.shape
    h_in = L.rmsnorm(x, blk["ln"])
    gates = jnp.einsum("bsd,dg->bsg", h_in, blk["w_in"])
    h, new_state = slstm_scan(gates, blk["r"], blk["bias"], cfg.n_heads, state,
                              mask)
    y = x + h.astype(x.dtype)
    y = y + L.swiglu(L.rmsnorm(y, blk["ln2"]), blk["ffn"])
    return y - x, new_state  # residual added by the caller


# ---------------------------------------------------------------------------
# full model


def _group_stacks(params: Params, cfg: ArchConfig):
    g, m_per = xlstm_groups(cfg)
    m_grouped = jax.tree.map(
        lambda a: a.reshape((g, m_per) + a.shape[1:]), params["mlstm"])
    return m_grouped, params["slstm"], g, m_per


def forward_xlstm(cfg: ArchConfig, params: Params, tokens: jax.Array,
                  mode="chunked"):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(tokens, params["embed"], dtype)
    m_grouped, s_stack, g, m_per = _group_stacks(params, cfg)

    def group_body(carry, xs):
        m_blks, s_blk = xs

        def inner(c, blk):
            y, _ = mlstm_block(c, blk, cfg, mode=mode)
            return L.constrain_residual(c + y), None

        carry, _ = lax.scan(_maybe_remat(inner, cfg), carry, m_blks)
        y, _ = slstm_block(carry, s_blk, cfg)
        return L.constrain_residual(carry + y), None

    x, _ = lax.scan(_maybe_remat(group_body, cfg), x, (m_grouped, s_stack))
    x = L.rmsnorm(x, params["ln_f"])
    return L.lm_logits(x, params["head"])


def prefill_xlstm(cfg: ArchConfig, params: Params, tokens: jax.Array,
                  length: Optional[jax.Array] = None):
    """``length``: optional (B,) valid prefix lengths for right-padded
    prompts; mLSTM gates and the sLSTM carry are masked so padded positions
    leave all recurrent state untouched."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    mask = None if length is None else \
        jnp.arange(S)[None, :] < length[:, None]
    x = L.embed_tokens(tokens, params["embed"], dtype)
    m_grouped, s_stack, g, m_per = _group_stacks(params, cfg)

    def group_body(carry, xs):
        m_blks, s_blk = xs

        def inner(c, blk):
            y, st = mlstm_block(c, blk, cfg, mask=mask)
            return L.constrain_residual(c + y), st

        carry, m_states = lax.scan(_maybe_remat(inner, cfg), carry, m_blks)
        y, s_state = slstm_block(carry, s_blk, cfg, mask=mask)
        return carry + y, (m_states, s_state)

    x, (m_states, s_states) = lax.scan(group_body, x, (m_grouped, s_stack))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(L.select_last(x, length), params["head"])
    flat_m = jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), m_states)  # (g*m_per, ...)
    cache = {"mC": flat_m[0], "mn": flat_m[1], "mm": flat_m[2],
             "sh": s_states[0], "sc": s_states[1],
             "sn": s_states[2], "sm": s_states[3]}
    return logits, cache


def decode_xlstm(cfg: ArchConfig, params: Params, cache, token: jax.Array,
                 pos):
    del pos  # state-based: position does not enter the recurrence
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(token, params["embed"], dtype)
    m_grouped, s_stack, g, m_per = _group_stacks(params, cfg)
    mC = cache["mC"].reshape((g, m_per) + cache["mC"].shape[1:])
    mn = cache["mn"].reshape((g, m_per) + cache["mn"].shape[1:])
    mm = cache["mm"].reshape((g, m_per) + cache["mm"].shape[1:])

    def group_body(carry, xs):
        m_blks, s_blk, C_, n_, m_, sh, sc, sn, sm = xs

        def inner(c, layer_xs):
            blk, Ci, ni, mi = layer_xs
            y, st = mlstm_block(c, blk, cfg, state=(Ci, ni, mi),
                                mode="recurrent")
            return c + y, st

        carry, (C_, n_, m_) = lax.scan(inner, carry, (m_blks, C_, n_, m_))
        y, (sh, sc, sn, sm) = slstm_block(carry, s_blk, cfg,
                                          state=(sh, sc, sn, sm))
        return carry + y, (C_, n_, m_, sh, sc, sn, sm)

    x, (mC, mn, mm, sh, sc, sn, sm) = lax.scan(
        group_body, x, (m_grouped, s_stack, mC, mn, mm,
                        cache["sh"], cache["sc"], cache["sn"], cache["sm"]))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(x, params["head"])
    cache = {"mC": mC.reshape((-1,) + mC.shape[2:]),
             "mn": mn.reshape((-1,) + mn.shape[2:]),
             "mm": mm.reshape((-1,) + mm.shape[2:]),
             "sh": sh, "sc": sc, "sn": sn, "sm": sm}
    return logits, cache


def xlstm_empty_state(cfg: ArchConfig, batch: int) -> Dict[str, jax.Array]:
    """The decode cache of a sequence that has seen no tokens yet.

    xLSTM's empty state is NOT all-zeros: the mLSTM and sLSTM stabilizers
    ``mm``/``sm`` start at -1e30 (so the first real token's gates dominate
    exactly as in ``mlstm_recurrent``/``slstm_scan`` with ``state=None``)
    and the sLSTM normalizer ``sn`` starts at the same 1e-6 floor the scan
    initializes with. This is the slot-reset seam the serving engine uses
    for chunked prefill and in-segment admission: decoding from this state
    is bit-identical to decoding from scratch.
    """
    g, m_per = xlstm_groups(cfg)
    n_m = g * m_per
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    f32 = jnp.float32
    return {
        "mC": jnp.zeros((n_m, batch, H, hd, hd), f32),
        "mn": jnp.zeros((n_m, batch, H, hd), f32),
        "mm": jnp.full((n_m, batch, H), -1e30, f32),
        "sh": jnp.zeros((g, batch, d), f32),
        "sc": jnp.zeros((g, batch, d), f32),
        "sn": jnp.full((g, batch, d), 1e-6, f32),
        "sm": jnp.full((g, batch, d), -1e30, f32),
    }


def xlstm_cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    del max_len  # state size is independent of context length
    g, m_per = xlstm_groups(cfg)
    n_m = g * m_per
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    f32 = jnp.float32
    return {
        "mC": jax.ShapeDtypeStruct((n_m, batch, H, hd, hd), f32),
        "mn": jax.ShapeDtypeStruct((n_m, batch, H, hd), f32),
        "mm": jax.ShapeDtypeStruct((n_m, batch, H), f32),
        "sh": jax.ShapeDtypeStruct((g, batch, d), f32),
        "sc": jax.ShapeDtypeStruct((g, batch, d), f32),
        "sn": jax.ShapeDtypeStruct((g, batch, d), f32),
        "sm": jax.ShapeDtypeStruct((g, batch, d), f32),
    }
