"""Shared model layers: norms, RoPE, GQA attention, SwiGLU, embeddings.

All layers are pure functions over explicit parameter pytrees so that
``jax.eval_shape`` can build full-size configs with zero allocation (dry-run)
and so the profiler can AOT-compile arbitrary variants.

Attention uses the grouped layout throughout: q is (B, S, K, G, D) where
K = n_kv_heads and G = q_per_kv; k/v are (B, T, K, D). This keeps GQA exact
without materializing repeated KV (which would inflate the decode-cache
memory term by G).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# initializers


def dense_init(rng, shape, dtype, in_axis: int = -2) -> jax.Array:
    """LeCun-normal style init, fan-in along ``in_axis``."""
    fan_in = shape[in_axis]
    scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def embed_init(rng, shape, dtype) -> jax.Array:
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)  # scale stored as (1 + s)


# ---------------------------------------------------------------------------
# rotary position embeddings


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, K?, G?, D) with positions (..., S) broadcastable over heads."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    # broadcast angles over any head dims between S and D
    for _ in range(x.ndim - angles.ndim):
        angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention


def constrain_residual(x: jax.Array) -> jax.Array:
    """Sequence-parallel sharding constraint on the (B, S, d) residual
    stream (no-op outside a mesh/dry-run context). Keeps the remat-saved
    carries sharded over the model axis."""
    from repro.distributed.parallel import get_activation_sharding
    ctx = get_activation_sharding()
    if ctx is None or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(ctx.batch(x.shape[0]), ctx.seq(x.shape[1]), None)
    return jax.lax.with_sharding_constraint(x, spec)


def _chunk_mask(causal, qi, kj, qb, kb, q_offset):
    t_idx = kj * kb + lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    if not causal:
        return jnp.ones((qb, kb), bool)
    s_idx = qi * qb + q_offset + lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    return t_idx <= s_idx


def _chunked_fwd(q, k, v, causal, q_offset, qb, kb):
    """Returns (out (B,S,K,G,D), lse (nq,B,K,G,qb))."""
    B, S, K, G, D = q.shape
    T = k.shape[1]
    nq, nk = S // qb, T // kb
    scale = D ** -0.5
    qr = q.reshape(B, nq, qb, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(B, nk, kb, K, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, kb, K, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk                      # qblk: (B, qb, K, G, D)

        def kv_step(carry, kj_blk):
            m_run, l_run, acc = carry
            kj, kblk, vblk = kj_blk
            s = jnp.einsum("bskgd,btkd->bkgst", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_chunk_mask(causal, qi, kj, qb, kb, q_offset),
                          s, -1e30)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_run, m_cur)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(v.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, K, G, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        a0 = jnp.zeros((B, K, G, qb, D), jnp.float32)
        (m, lsum, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(nk), kr, vr))
        lsum = jnp.maximum(lsum, 1e-30)
        out = acc / lsum[..., None]
        lse = m + jnp.log(lsum)                # (B, K, G, qb)
        return None, (out.astype(q.dtype), lse)

    _, (outs, lses) = lax.scan(q_step, None, (jnp.arange(nq), qr))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, K, G, D)
    return out, lses


def _chunked_bwd(q, k, v, out, lse, dout, causal, q_offset, qb, kb):
    """FlashAttention-style recomputing backward: nothing S x T is stored."""
    B, S, K, G, D = q.shape
    T = k.shape[1]
    nq, nk = S // qb, T // kb
    scale = D ** -0.5
    qr = q.reshape(B, nq, qb, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(B, nk, kb, K, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, kb, K, D).transpose(1, 0, 2, 3, 4)
    do_r = dout.reshape(B, nq, qb, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    o_r = out.reshape(B, nq, qb, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    # D_i = rowsum(dOut * Out): (nq, B, K, G, qb)
    delta = jnp.einsum("nbskgd,nbskgd->nbkgs", do_r.astype(jnp.float32),
                       o_r.astype(jnp.float32))

    def kv_step(_, kj_blk):
        kj, kblk, vblk = kj_blk

        def q_step(carry, qi_blk):
            dk_acc, dv_acc = carry
            qi, qblk, doblk, lse_i, delta_i = qi_blk
            s = jnp.einsum("bskgd,btkd->bkgst", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = _chunk_mask(causal, qi, kj, qb, kb, q_offset)
            s = jnp.where(mask, s, -1e30)
            p = jnp.exp(s - lse_i[..., None])            # (B,K,G,qb,kb)
            dp = jnp.einsum("bskgd,btkd->bkgst",
                            doblk.astype(jnp.float32),
                            vblk.astype(jnp.float32))
            ds = p * (dp - delta_i[..., None])
            dq_i = jnp.einsum("bkgst,btkd->bskgd", ds,
                              kblk.astype(jnp.float32)) * scale
            dk_acc = dk_acc + jnp.einsum(
                "bkgst,bskgd->btkd", ds,
                qblk.astype(jnp.float32)) * scale
            dv_acc = dv_acc + jnp.einsum(
                "bkgst,bskgd->btkd", p, doblk.astype(jnp.float32))
            return (dk_acc, dv_acc), dq_i

        dk0 = jnp.zeros((B, kb, K, D), jnp.float32)
        dv0 = jnp.zeros((B, kb, K, D), jnp.float32)
        (dk_j, dv_j), dq_parts = lax.scan(
            q_step, (dk0, dv0), (jnp.arange(nq), qr, do_r, lse, delta))
        return None, (dk_j, dv_j, dq_parts)

    _, (dks, dvs, dq_parts) = lax.scan(kv_step, None,
                                       (jnp.arange(nk), kr, vr))
    # dq_parts: (nk, nq, B, qb, K, G, D) -> sum over kv blocks
    dq = jnp.sum(dq_parts, axis=0).transpose(1, 0, 2, 3, 4, 5) \
        .reshape(B, S, K, G, D).astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, T, K, D).astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, T, K, D).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attention_chunked_cvjp(q, k, v, causal, q_offset, qb, kb):
    out, _ = _chunked_fwd(q, k, v, causal, q_offset, qb, kb)
    return out


def _attention_chunked_cvjp_fwd(q, k, v, causal, q_offset, qb, kb):
    out, lse = _chunked_fwd(q, k, v, causal, q_offset, qb, kb)
    return out, (q, k, v, out, lse)


def _attention_chunked_cvjp_bwd(causal, q_offset, qb, kb, res, dout):
    q, k, v, out, lse = res
    return _chunked_bwd(q, k, v, out, lse, dout, causal, q_offset, qb, kb)


_attention_chunked_cvjp.defvjp(_attention_chunked_cvjp_fwd,
                               _attention_chunked_cvjp_bwd)


def _attention_chunked(q, k, v, *, causal, q_offset, kv_valid_len,
                       q_block: int = 256, k_block: int = 512) -> jax.Array:
    """Flash-style online-softmax attention in pure XLA with a recomputing
    custom-vjp backward: the S x T score matrix never materializes in either
    pass. Used for long-sequence lowering when the Pallas kernel can't
    target the backend (the dry-run path). kv_valid_len is not supported
    here (callers fall back to the plain path)."""
    assert kv_valid_len is None
    B, S, K, G, D = q.shape
    T = k.shape[1]
    qb = min(q_block, S)
    kb = min(k_block, T)
    assert S % qb == 0 and T % kb == 0, (S, T, qb, kb)
    return _attention_chunked_cvjp(q, k, v, causal, int(q_offset), qb, kb)


def decode_attention_splitk(q, kc, vc, valid_len, ctx) -> jax.Array:
    """Flash-decode over a sequence-sharded KV cache via shard_map.

    q: (B, 1, K, G, D) replicated over the model axis; kc/vc: (B, T, K, D)
    sharded T over the model axis. Each shard computes a local
    online-softmax partial (m, l, acc); the combine is three tiny psums of
    (B,K,G,{1,D}) — no score or cache all-gather (§Perf A-iter2).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    B, _, K, G, D = q.shape
    T = kc.shape[1]
    m_axis = ctx.model_axis
    T_local = T // ctx.model_size
    dax = ctx.batch(B)
    q_spec = P(dax, None, None, None, None)
    kv_spec = P(dax, m_axis, None, None)
    scalar = P()

    def local_fn(q_l, k_l, v_l, vlen):
        # q_l: (B_l, 1, K, G, D); k_l/v_l: (B_l, T_local, K, D)
        offset = jax.lax.axis_index(m_axis) * T_local
        s = jnp.einsum("bskgd,btkd->bkgst", q_l, k_l,
                       preferred_element_type=jnp.float32) * (D ** -0.5)
        t_idx = offset + jnp.arange(T_local)
        s = jnp.where(t_idx[None, None, None, None, :] < vlen, s, -1e30)
        m_loc = jnp.max(s, axis=-1, keepdims=True)          # (b,k,g,1,1)
        m_glob = jax.lax.pmax(m_loc, m_axis)
        p = jnp.exp(s - m_glob)
        l_loc = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bkgst,btkd->bskgd", p.astype(v_l.dtype), v_l,
                         preferred_element_type=jnp.float32)
        l_glob = jax.lax.psum(l_loc, m_axis)                # (b,k,g,1,1)
        acc = jax.lax.psum(acc, m_axis)                     # (b,1,k,g,D)
        out = acc / jnp.maximum(l_glob[:, :, :, :, 0], 1e-30
                                ).transpose(0, 3, 1, 2)[..., None]
        return out.astype(q_l.dtype)

    fn = shard_map(local_fn, mesh=ctx.mesh,
                   in_specs=(q_spec, kv_spec, kv_spec, scalar),
                   out_specs=q_spec, check_rep=False)
    return fn(q, kc, vc, jnp.asarray(valid_len, jnp.int32))


def attention_core(
    q: jax.Array,                 # (B, S, K, G, D)
    k: jax.Array,                 # (B, T, K, D)
    v: jax.Array,                 # (B, T, K, D)
    *,
    causal: bool,
    q_offset: Any = 0,            # query position offset (decode: cache_len)
    kv_valid_len: Optional[Any] = None,   # mask kv positions >= this;
                                          # scalar, or (B,) per-sequence
    impl: str = "xla",
) -> jax.Array:
    """Grouped-query attention. Returns (B, S, K, G, D)."""
    # Per-sequence valid lengths (continuous batching: each batch slot is at
    # a different decode position) only lower through the plain XLA path —
    # the Pallas/split-K kernels take a single scalar length.
    vec_valid = kv_valid_len is not None and jnp.ndim(kv_valid_len) > 0
    if vec_valid:
        impl = "xla"
    if impl == "xla_chunked" and q.shape[1] == 1 and kv_valid_len is not None:
        # decode against a long cache: use the split-K shard_map path when
        # the cache is sequence-sharded over the model axis
        from repro.distributed.parallel import get_activation_sharding
        ctx = get_activation_sharding()
        if ctx is not None and ctx.mesh is not None \
                and k.shape[1] > 1 and k.shape[1] % ctx.model_size == 0 \
                and k.shape[2] % ctx.model_size != 0:
            # (KV-head-sharded caches keep the GSPMD path: resharding the
            # cache into the split-K layout would cost an all-to-all)
            return decode_attention_splitk(q, k, v, kv_valid_len, ctx)
    if impl.startswith("pallas"):
        from repro.kernels import ops as kops
        return kops.flash_attention_grouped(
            q, k, v, causal=causal, q_offset=q_offset,
            kv_valid_len=kv_valid_len,
            interpret=impl == "pallas_interpret")
    if impl == "xla_chunked" and kv_valid_len is None \
            and q.shape[1] > 256 and q.shape[1] % 256 == 0 \
            and k.shape[1] % 512 == 0:
        return _attention_chunked(q, k, v, causal=causal, q_offset=q_offset,
                                  kv_valid_len=None)
    B, S, K, G, D = q.shape
    T = k.shape[1]
    scale = D ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal or kv_valid_len is not None:
        t_idx = jnp.arange(T)
        mask = jnp.ones((S, T), bool)
        if causal:
            s_idx = jnp.arange(S)[:, None] + q_offset
            mask = t_idx[None, :] <= s_idx
        if kv_valid_len is not None and not vec_valid:
            mask = mask & (t_idx[None, :] < kv_valid_len)
        if vec_valid:
            # (B, 1, 1, S, T) mask broadcasting over scores (B, K, G, S, T)
            per_seq = t_idx[None, :] < jnp.reshape(kv_valid_len, (-1, 1))
            mask = mask[None] & per_seq[:, None, :]
            mask = mask[:, None, None]
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def paged_attention_core(
    q: jax.Array,                 # (B, 1, K, G, D) one decode token per slot
    k_pool: jax.Array,            # (n_pages, page_size, K, D) shared pool
    v_pool: jax.Array,
    block_table: jax.Array,       # (B, P) page ids, sentinel = n_pages
    *,
    kv_valid_len: Any,            # scalar or (B,) per-slot valid lengths
    impl: str = "xla",
) -> jax.Array:
    """Decode attention over a paged KV cache (vLLM-style block tables).

    On the Pallas path the kernel walks the block table directly (HBM
    traffic is one pass over the *live* pages); the XLA path materializes
    the slot's logical view with a page gather and reuses the standard
    masked ``attention_core``, which keeps outputs bit-identical to the
    contiguous layout (P * page_size == S_max, and positions beyond
    ``kv_valid_len`` mask to exact zeros either way).
    """
    from repro.models import kvcache as KV
    if impl.startswith("pallas") and q.shape[1] == 1:
        from repro.kernels.decode_attention import paged_decode_attention
        out = paged_decode_attention(
            q[:, 0], k_pool, v_pool, block_table, kv_valid_len,
            interpret=impl == "pallas_interpret")
        return out[:, None]
    kc = KV.gather_block_kv(k_pool, block_table)
    vc = KV.gather_block_kv(v_pool, block_table)
    return attention_core(q, kc, vc, causal=False,
                          kv_valid_len=kv_valid_len, impl="xla")


def paged_update_attend(
    q: jax.Array,                 # (B, 1, K, G, D) one decode token per slot
    k: jax.Array,                 # (B, 1, K, D) the token's fresh k/v rows
    v: jax.Array,
    k_pool: jax.Array,            # (n_phys, page_size, K, D) shared pool
    v_pool: jax.Array,
    block_table: jax.Array,       # (B, P) page ids, sentinel = n_phys - 1
    pos: Any,                     # scalar or (B,) write position per slot
    *,
    impl: str = "xla",
) -> tuple:
    """One decode step's paged KV write + attend; returns (o, k_pool,
    v_pool).

    On the Pallas path both halves run in one fused kernel
    (``fused_paged_decode_attention``): the new row is injected into the
    write page's VMEM tile before the scores see it and the page flushes
    back through an aliased output, so the decode loop carries no
    separate XLA pool scatter. This requires the engine's pallas-paged
    pool layout (one trash page at the sentinel index, written pages
    private to their slot — see the kernel's docstring). The XLA path
    keeps the two-op form (scatter with sentinel drop, then the gathered
    masked attend), which is bit-identical to the contiguous layout.
    """
    from repro.models import kvcache as KV
    if impl.startswith("pallas") and q.shape[1] == 1:
        from repro.kernels.decode_attention import \
            fused_paged_decode_attention
        o, k_pool, v_pool = fused_paged_decode_attention(
            q[:, 0], k[:, 0], v[:, 0], k_pool, v_pool, block_table, pos,
            interpret=impl == "pallas_interpret")
        return o[:, None], k_pool, v_pool
    k_pool, v_pool = KV.paged_update_layer_cache(
        k_pool, v_pool, k, v, block_table, pos)
    o = paged_attention_core(q, k_pool, v_pool, block_table,
                             kv_valid_len=pos + 1, impl=impl)
    return o, k_pool, v_pool


def attn_params_init(rng, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                     dtype) -> Params:
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], (d_model, n_kv, n_heads // n_kv, head_dim),
                         dtype, in_axis=0),
        "wk": dense_init(ks[1], (d_model, n_kv, head_dim), dtype, in_axis=0),
        "wv": dense_init(ks[2], (d_model, n_kv, head_dim), dtype, in_axis=0),
        "wo": dense_init(ks[3], (n_kv, n_heads // n_kv, head_dim, d_model),
                         dtype, in_axis=0),
    }


def attn_qkv(x: jax.Array, p: Params) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    return q, k, v


def attn_out(o: jax.Array, p: Params) -> jax.Array:
    return jnp.einsum("bskgh,kghd->bsd", o, p["wo"])


def self_attention(
    x: jax.Array, p: Params, cfg_theta: float, *,
    causal: bool = True,
    positions: Optional[jax.Array] = None,
    impl: str = "xla",
    rope: bool = True,
) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = attn_qkv(x, p)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if rope:
        q = apply_rope(q, positions, cfg_theta)
        k = apply_rope(k, positions, cfg_theta)
    o = attention_core(q, k, v, causal=causal, impl=impl)
    return attn_out(o, p)


# ---------------------------------------------------------------------------
# SwiGLU MLP


def mlp_params_init(rng, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype, in_axis=0),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype, in_axis=0),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype, in_axis=0),
    }


def swiglu(x: jax.Array, p: Params) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# embeddings / lm head


def embed_tokens(tokens: jax.Array, table: jax.Array, dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def select_last(x: jax.Array, length: Optional[jax.Array]) -> jax.Array:
    """Select the last *valid* position per sequence: x (B, S, d) -> (B, 1, d).

    ``length`` is an optional (B,) int array of valid prefix lengths (prompts
    right-padded to a shared bucket); None means the full sequence is valid,
    which reduces to ``x[:, -1:]``. Used by prefill so the engine reads the
    next-token logits at position length-1 rather than at the padded end.
    """
    if length is None:
        return x[:, -1:]
    idx = jnp.clip(length.astype(jnp.int32) - 1, 0, x.shape[1] - 1)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def lm_logits(x: jax.Array, head: jax.Array) -> jax.Array:
    # head: (vocab, d_model); logits in f32 for a stable softmax/xent
    return jnp.einsum("bsd,vd->bsv", x, head,
                      preferred_element_type=jnp.float32)


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits (B,S,V) f32, targets (B,S) int.

    The gold logit is extracted with a mask-reduce rather than
    take_along_axis: a gather along a vocab-sharded axis makes GSPMD
    replicate the full logits; the mask-reduce stays sharded (partial sum +
    small all-reduce)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    mask = vocab_iota == targets[..., None]
    gold = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)
