"""Dense decoder-only transformer (llama family), plus the VLM (cross-attn
image layers) and audio (enc-dec) backbones which reuse the same blocks.

All stacks are scanned over layers (params stacked on a leading L dim) so the
HLO stays compact for 100-layer configs; ``cfg.remat`` wraps the scan body in
``jax.checkpoint`` for training.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import kvcache as KV

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# parameter init


def _stacked_attn_init(rng, n: int, cfg: ArchConfig, dtype,
                       kv_heads: Optional[int] = None) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    nk = kv_heads if kv_heads is not None else cfg.n_kv_heads
    g = cfg.n_heads // nk
    ks = jax.random.split(rng, 4)
    return {
        "wq": L.dense_init(ks[0], (n, d, nk, g, hd), dtype, in_axis=1),
        "wk": L.dense_init(ks[1], (n, d, nk, hd), dtype, in_axis=1),
        "wv": L.dense_init(ks[2], (n, d, nk, hd), dtype, in_axis=1),
        "wo": L.dense_init(ks[3], (n, nk, g, hd, d), dtype, in_axis=-1),
    }


def _stacked_mlp_init(rng, n: int, cfg: ArchConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": L.dense_init(ks[0], (n, d, f), dtype, in_axis=1),
        "w_up": L.dense_init(ks[1], (n, d, f), dtype, in_axis=1),
        "w_down": L.dense_init(ks[2], (n, f, d), dtype, in_axis=1),
    }


def _block_stack_init(rng, n: int, cfg: ArchConfig, dtype) -> Params:
    ka, km = jax.random.split(rng)
    return {
        "attn": _stacked_attn_init(ka, n, cfg, dtype),
        "mlp": _stacked_mlp_init(km, n, cfg, dtype),
        "ln1": jnp.zeros((n, cfg.d_model), dtype),
        "ln2": jnp.zeros((n, cfg.d_model), dtype),
    }


def init_dense(cfg: ArchConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kh = jax.random.split(rng, 3)
    return {
        "embed": L.embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "layers": _block_stack_init(kl, cfg.n_layers, cfg, dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "head": L.embed_init(kh, (cfg.vocab, cfg.d_model), dtype),
    }


def init_vlm(cfg: ArchConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    n_cross = cfg.n_layers // cfg.cross_attn_every
    n_self = cfg.n_layers - n_cross
    ke, ks, kc, kh = jax.random.split(rng, 4)
    p = {
        "embed": L.embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "layers": _block_stack_init(ks, n_self, cfg, dtype),
        "cross_layers": _block_stack_init(kc, n_cross, cfg, dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "head": L.embed_init(kh, (cfg.vocab, cfg.d_model), dtype),
    }
    return p


def init_audio(cfg: ArchConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kenc, kdec, kx, kh = jax.random.split(rng, 5)
    return {
        "embed": L.embed_init(ke, (cfg.vocab, cfg.d_model), dtype),
        "encoder": _block_stack_init(kenc, cfg.n_encoder_layers, cfg, dtype),
        "decoder": _block_stack_init(kdec, cfg.n_layers, cfg, dtype),
        "cross": _block_stack_init(kx, cfg.n_layers, cfg, dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "head": L.embed_init(kh, (cfg.vocab, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# block bodies


def _self_block(x, blk, cfg: ArchConfig, *, causal=True, positions=None,
                rope=True, kv_valid_len=None):
    h = L.rmsnorm(x, blk["ln1"])
    q, k, v = L.attn_qkv(h, blk["attn"])
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    o = L.attention_core(q, k, v, causal=causal, kv_valid_len=kv_valid_len,
                         impl=cfg.attention_impl)
    x = x + L.attn_out(o, blk["attn"])
    x = x + L.swiglu(L.rmsnorm(x, blk["ln2"]), blk["mlp"])
    return L.constrain_residual(x)


def _cross_block(x, blk, ctx, cfg: ArchConfig, valid_len=None):
    """Cross-attention block: queries from x, KV from ctx (no RoPE/causality).

    ``valid_len``: optional scalar or (B,) true context lengths; padded
    context rows mask out of the softmax (exact zeros)."""
    h = L.rmsnorm(x, blk["ln1"])
    q = jnp.einsum("bsd,dkgh->bskgh", h, blk["attn"]["wq"])
    k = jnp.einsum("btd,dkh->btkh", ctx, blk["attn"]["wk"])
    v = jnp.einsum("btd,dkh->btkh", ctx, blk["attn"]["wv"])
    o = L.attention_core(q, k, v, causal=False, kv_valid_len=valid_len,
                         impl=cfg.attention_impl)
    x = x + L.attn_out(o, blk["attn"])
    x = x + L.swiglu(L.rmsnorm(x, blk["ln2"]), blk["mlp"])
    return L.constrain_residual(x)


def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan_blocks(x, stack: Params, cfg: ArchConfig, *, causal=True,
                 positions=None, rope=True, kv_valid_len=None):
    def body(carry, blk):
        return _self_block(carry, blk, cfg, causal=causal,
                           positions=positions, rope=rope,
                           kv_valid_len=kv_valid_len), None
    x, _ = lax.scan(_maybe_remat(body, cfg), x, stack)
    return x


# ---------------------------------------------------------------------------
# dense: train forward / prefill / decode


def forward_dense(cfg: ArchConfig, params: Params, tokens: jax.Array) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(tokens, params["embed"], dtype)
    x = _scan_blocks(x, params["layers"], cfg)
    x = L.rmsnorm(x, params["ln_f"])
    return L.lm_logits(x, params["head"])


def _prefill_scan(x, stack, cfg: ArchConfig, positions):
    """Forward over layers, emitting per-layer (k, v) as scan ys."""
    def body(carry, blk):
        h = L.rmsnorm(carry, blk["ln1"])
        q, k, v = L.attn_qkv(h, blk["attn"])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.attention_core(q, k, v, causal=True, impl=cfg.attention_impl)
        out = carry + L.attn_out(o, blk["attn"])
        out = out + L.swiglu(L.rmsnorm(out, blk["ln2"]), blk["mlp"])
        return L.constrain_residual(out), (k, v)
    x, (ks, vs) = lax.scan(_maybe_remat(body, cfg), x, stack)
    return x, ks, vs


def prefill_dense(cfg: ArchConfig, params: Params, tokens: jax.Array,
                  length: Optional[jax.Array] = None):
    """``length``: optional (B,) valid prefix lengths for right-padded
    prompts; next-token logits are read at position length-1 (causal
    attention keeps valid positions independent of right padding)."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = L.embed_tokens(tokens, params["embed"], dtype)
    x, ks, vs = _prefill_scan(x, params["layers"], cfg, positions)
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(L.select_last(x, length), params["head"])
    return logits, {"k": ks, "v": vs}


def decode_positions(pos, batch: int) -> jax.Array:
    """(B, 1) RoPE positions from a shared scalar or per-sequence (B,) pos."""
    if jnp.ndim(pos) == 0:
        return jnp.full((batch, 1), pos)
    return jnp.reshape(pos, (batch, 1))


def _decode_block(x, blk, kc, vc, pos, cfg: ArchConfig, bt=None):
    """One decode step through one block. x: (B,1,d).

    ``pos`` is a shared scalar or a per-sequence (B,) vector of positions.
    With ``bt=None`` kc/vc are contiguous (B,Smax,K,D) slot rows; with a
    (B, P) block table they are shared (n_pages, page_size, K, D) pools and
    the write/attend both route through the slot's block table.
    """
    h = L.rmsnorm(x, blk["ln1"])
    q, k, v = L.attn_qkv(h, blk["attn"])
    positions = decode_positions(pos, x.shape[0])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if bt is None:
        kc, vc = KV.update_layer_cache(kc, vc, k, v, pos)
        o = L.attention_core(q, kc, vc, causal=False, kv_valid_len=pos + 1,
                             impl=cfg.attention_impl)
    else:
        o, kc, vc = L.paged_update_attend(q, k, v, kc, vc, bt, pos,
                                          impl=cfg.attention_impl)
    x = x + L.attn_out(o, blk["attn"])
    x = x + L.swiglu(L.rmsnorm(x, blk["ln2"]), blk["mlp"])
    return x, kc, vc


def decode_dense(cfg: ArchConfig, params: Params, cache, token: jax.Array,
                 pos) -> Tuple[jax.Array, Any]:
    """serve_step: one new token against the cache. token: (B,1) int32.

    ``cache`` may carry a ``"bt"`` block table, in which case its k/v
    leaves are shared page pools (see ``repro.models.kvcache``)."""
    dtype = jnp.dtype(cfg.dtype)
    bt = cache.get("bt")
    x = L.embed_tokens(token, params["embed"], dtype)

    def body(carry, xs):
        blk, kc, vc = xs
        out, kc, vc = _decode_block(carry, blk, kc, vc, pos, cfg, bt=bt)
        return out, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(x, params["head"])
    out_cache = {"k": ks, "v": vs}
    if bt is not None:
        out_cache["bt"] = bt
    return logits, out_cache


# ---------------------------------------------------------------------------
# VLM: self stack with interleaved cross-attention groups


def _vlm_scan(x, params, cfg: ArchConfig, image_embeds, decode_state=None,
              pos=None):
    """Grouped scan: (cross_every - 1) self layers then 1 cross layer.

    decode_state: None for full-seq forward; else dict with self k/v caches
    stacked (n_self, ...) and cross k/v stacked (n_cross, ...).
    """
    n_cross = cfg.n_layers // cfg.cross_attn_every
    n_self_per = cfg.cross_attn_every - 1

    def regroup(stack, n_groups, per):
        return jax.tree.map(
            lambda a: a.reshape((n_groups, per) + a.shape[1:]), stack)

    self_grouped = regroup(params["layers"], n_cross, n_self_per)

    def group_body(carry, xs):
        self_blks, cross_blk = xs
        def inner(c, blk):
            return _self_block(c, blk, cfg), None
        carry, _ = lax.scan(_maybe_remat(inner, cfg), carry, self_blks)
        # remat the cross block itself (group-level remat would recompute
        # the whole 9-layer inner scan a second time: §Perf B-iter1)
        cross = _maybe_remat(
            lambda c, blk: _cross_block(c, blk, image_embeds, cfg), cfg)
        carry = cross(carry, cross_blk)
        return carry, None

    x, _ = lax.scan(group_body, x,
                    (self_grouped, params["cross_layers"]))
    return x


def forward_vlm(cfg: ArchConfig, params: Params, tokens: jax.Array,
                image_embeds: jax.Array) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_tokens(tokens, params["embed"], dtype)
    x = _vlm_scan(x, params, cfg, image_embeds.astype(dtype))
    x = L.rmsnorm(x, params["ln_f"])
    return L.lm_logits(x, params["head"])


def prefill_vlm(cfg: ArchConfig, params: Params, tokens: jax.Array,
                image_embeds: jax.Array,
                length: Optional[jax.Array] = None):
    """Prefill emitting self-attn KV per self layer + cross KV per cross layer."""
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    n_cross = cfg.n_layers // cfg.cross_attn_every
    n_self_per = cfg.cross_attn_every - 1
    img = image_embeds.astype(dtype)

    self_grouped = jax.tree.map(
        lambda a: a.reshape((n_cross, n_self_per) + a.shape[1:]),
        params["layers"])

    def group_body(carry, xs):
        self_blks, cross_blk = xs
        def inner(c, blk):
            h = L.rmsnorm(c, blk["ln1"])
            q, k, v = L.attn_qkv(h, blk["attn"])
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            o = L.attention_core(q, k, v, causal=True, impl=cfg.attention_impl)
            out = c + L.attn_out(o, blk["attn"])
            out = out + L.swiglu(L.rmsnorm(out, blk["ln2"]), blk["mlp"])
            return L.constrain_residual(out), (k, v)
        carry, (ks, vs) = lax.scan(_maybe_remat(inner, cfg), carry, self_blks)
        xk = jnp.einsum("btd,dkh->btkh", img, cross_blk["attn"]["wk"])
        xv = jnp.einsum("btd,dkh->btkh", img, cross_blk["attn"]["wv"])
        carry = _cross_block(carry, cross_blk, img, cfg)
        return carry, (ks, vs, xk, xv)

    x = L.embed_tokens(tokens, params["embed"], dtype)
    x, (ks, vs, xks, xvs) = lax.scan(_maybe_remat(group_body, cfg), x,
                                     (self_grouped, params["cross_layers"]))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(L.select_last(x, length), params["head"])
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}
    return logits, cache


def decode_vlm(cfg: ArchConfig, params: Params, cache, token: jax.Array, pos):
    dtype = jnp.dtype(cfg.dtype)
    n_cross = cfg.n_layers // cfg.cross_attn_every
    n_self_per = cfg.cross_attn_every - 1
    bt = cache.get("bt")
    x = L.embed_tokens(token, params["embed"], dtype)

    self_grouped = jax.tree.map(
        lambda a: a.reshape((n_cross, n_self_per) + a.shape[1:]),
        params["layers"])

    def group_body(carry, xs):
        self_blks, cross_blk, kc, vc, xk, xv = xs

        def inner(c, layer_xs):
            blk, k1, v1 = layer_xs
            out, k1, v1 = _decode_block(c, blk, k1, v1, pos, cfg, bt=bt)
            return out, (k1, v1)

        carry, (kc, vc) = lax.scan(inner, carry, (self_blks, kc, vc))
        # cross attention against the cached image KV
        h = L.rmsnorm(carry, cross_blk["ln1"])
        q = jnp.einsum("bsd,dkgh->bskgh", h, cross_blk["attn"]["wq"])
        o = L.attention_core(q, xk, xv, causal=False, impl=cfg.attention_impl)
        carry = carry + L.attn_out(o, cross_blk["attn"])
        carry = carry + L.swiglu(L.rmsnorm(carry, cross_blk["ln2"]),
                                 cross_blk["mlp"])
        return carry, (kc, vc)

    x, (ks, vs) = lax.scan(group_body, x,
                           (self_grouped, params["cross_layers"],
                            cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(x, params["head"])
    out_cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
    if bt is not None:
        out_cache["bt"] = bt
    return logits, out_cache


# ---------------------------------------------------------------------------
# audio (enc-dec): stub frame embeddings in, decoder tokens out


def _encode(cfg: ArchConfig, params: Params, frames: jax.Array,
            valid_len=None) -> jax.Array:
    """frames: (B, T, d_model) precomputed stub embeddings.

    ``valid_len``: optional (B,) true frame counts for right-padded frame
    batches; padded rows are masked out of the (bidirectional) encoder
    self-attention so valid encoder outputs are independent of padding."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    return _scan_blocks(x, params["encoder"], cfg, causal=False,
                        kv_valid_len=valid_len)


def forward_audio(cfg: ArchConfig, params: Params, tokens: jax.Array,
                  frames: jax.Array) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    enc = _encode(cfg, params, frames)
    x = L.embed_tokens(tokens, params["embed"], dtype)

    def body(carry, xs):
        dec_blk, cross_blk = xs
        carry = _self_block(carry, dec_blk, cfg, causal=True)
        carry = _cross_block(carry, cross_blk, enc, cfg)
        return carry, None

    x, _ = lax.scan(_maybe_remat(body, cfg), x,
                    (params["decoder"], params["cross"]))
    x = L.rmsnorm(x, params["ln_f"])
    return L.lm_logits(x, params["head"])


def prefill_audio(cfg: ArchConfig, params: Params, tokens: jax.Array,
                  frames: jax.Array, length: Optional[jax.Array] = None):
    """``length``: optional (B,) valid prefix lengths, shared by the token
    prompt and the frame stream. Encoder self-attention and decoder cross-
    attention both mask by the true encoder length, so padded encoder rows
    contribute exact zeros — outputs no longer depend on the padded width,
    and the paged cache's dropped writes on padding rows are unobservable.
    The true length rides in the cache (``enc_len``) for decode."""
    dtype = jnp.dtype(cfg.dtype)
    enc = _encode(cfg, params, frames, valid_len=length)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    enc_len = length if length is not None \
        else jnp.full((B,), frames.shape[1], jnp.int32)
    enc_len = enc_len.astype(jnp.int32)
    x = L.embed_tokens(tokens, params["embed"], dtype)

    def body(carry, xs):
        dec_blk, cross_blk = xs
        h = L.rmsnorm(carry, dec_blk["ln1"])
        q, k, v = L.attn_qkv(h, dec_blk["attn"])
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.attention_core(q, k, v, causal=True, impl=cfg.attention_impl)
        carry = carry + L.attn_out(o, dec_blk["attn"])
        carry = L.constrain_residual(
            carry + L.swiglu(L.rmsnorm(carry, dec_blk["ln2"]),
                             dec_blk["mlp"]))
        xk = jnp.einsum("btd,dkh->btkh", enc, cross_blk["attn"]["wk"])
        xv = jnp.einsum("btd,dkh->btkh", enc, cross_blk["attn"]["wv"])
        carry = _cross_block(carry, cross_blk, enc, cfg, valid_len=length)
        return carry, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = lax.scan(body, x,
                                     (params["decoder"], params["cross"]))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(L.select_last(x, length), params["head"])
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs,
                    "enc_len": enc_len}


def decode_audio(cfg: ArchConfig, params: Params, cache, token: jax.Array, pos):
    dtype = jnp.dtype(cfg.dtype)
    bt = cache.get("bt")
    enc_len = cache["enc_len"]
    x = L.embed_tokens(token, params["embed"], dtype)

    def body(carry, xs):
        dec_blk, cross_blk, kc, vc, xk, xv = xs
        carry, kc, vc = _decode_block(carry, dec_blk, kc, vc, pos, cfg,
                                      bt=bt)
        h = L.rmsnorm(carry, cross_blk["ln1"])
        q = jnp.einsum("bsd,dkgh->bskgh", h, cross_blk["attn"]["wq"])
        if bt is None:
            o = L.attention_core(q, xk, xv, causal=False,
                                 kv_valid_len=enc_len,
                                 impl=cfg.attention_impl)
        else:
            o = L.paged_attention_core(q, xk, xv, bt, kv_valid_len=enc_len,
                                       impl=cfg.attention_impl)
        carry = carry + L.attn_out(o, cross_blk["attn"])
        carry = carry + L.swiglu(L.rmsnorm(carry, cross_blk["ln2"]),
                                 cross_blk["mlp"])
        return carry, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["decoder"], params["cross"],
                                     cache["k"], cache["v"],
                                     cache["xk"], cache["xv"]))
    x = L.rmsnorm(x, params["ln_f"])
    logits = L.lm_logits(x, params["head"])
    out_cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                 "enc_len": enc_len}
    if bt is not None:
        out_cache["bt"] = bt
    return logits, out_cache
