"""Unified model interface over the 10 assigned architecture families.

``build_model(cfg, parallel=None)`` returns a ``Model`` with:
  * ``init(rng) -> params``
  * ``forward(params, batch) -> logits``          (full-sequence, causal)
  * ``loss(params, batch) -> scalar``             (mean token cross-entropy)
  * ``prefill(params, batch) -> (logits, cache)``
  * ``decode(params, cache, token, pos) -> (logits, cache)``  (serve_step)
  * ``cache_shapes(batch, max_len) -> pytree of ShapeDtypeStruct``

All functions are jit/pjit-compatible and usable under ``jax.eval_shape``.

Serving extensions (used by the continuous-batching engine):
  * ``batch`` may carry ``"length"``, a (B,) int array of valid prefix
    lengths for prompts right-padded to a shared bucket. Prefill then reads
    the next-token logits at position length-1 (still returning (B, 1, V))
    and — for the recurrent families — masks the recurrence so padded
    positions leave the carried state untouched.
  * ``decode``'s ``pos`` may be a (B,) vector of per-sequence positions
    instead of a shared scalar (each batch slot at its own decode offset).
  * ``decode``'s cache may carry a ``"bt"`` block table (B, P), in which
    case the attention k/v leaves are shared page pools
    (``repro.models.kvcache`` paged layout) and writes/reads route through
    the slot's block table; recurrent O(1) state leaves stay slot-indexed.
    Supported by the dense/moe/hybrid/vlm/audio decode paths (audio
    carries its true encoder length per slot as an ``enc_len`` cache leaf
    and masks cross-attention by it).
  * ``empty_state(batch, max_len)`` returns the decode cache of a
    sequence that has seen no tokens — the slot-reset seam the serving
    engine uses for chunked prefill and in-segment admission (all-zeros
    except xLSTM's -inf stabilizers).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X

Batch = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]
    forward: Callable[..., jax.Array]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    cache_shapes: Callable[..., Any]
    # empty_state(batch, max_len, **kw) -> concrete cache pytree for a
    # sequence that has seen no tokens: the slot-reset seam the serving
    # engine uses for chunked prefill and in-segment admission. Defaults
    # to all-zeros (valid for attention KV and SSM/conv states); xLSTM
    # overrides it (its sLSTM/mLSTM stabilizers start at -inf, not zero).
    empty_state: Optional[Callable[..., Any]] = None

    def loss(self, params, batch: Batch) -> jax.Array:
        logits = self.forward(params, batch)
        return L.cross_entropy(logits, batch["targets"])


def _zeros_empty_state(cache_shapes: Callable[..., Any]):
    def empty_state(batch: int, max_len: int, **kw):
        shapes = cache_shapes(batch, max_len, **kw)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    return empty_state


def _attn_cache_shapes(cfg: ArchConfig, n_layers: int, batch: int,
                       max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    sh = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(sh, dtype),
            "v": jax.ShapeDtypeStruct(sh, dtype)}


def build_model(cfg: ArchConfig, parallel=None) -> Model:
    fam = cfg.family

    if fam in ("dense",):
        cs = lambda batch, max_len, **kw: _attn_cache_shapes(  # noqa: E731
            cfg, cfg.n_layers, batch, max_len)
        return Model(
            cfg=cfg,
            init=lambda rng: T.init_dense(cfg, rng),
            forward=lambda p, b: T.forward_dense(cfg, p, b["tokens"]),
            prefill=lambda p, b: T.prefill_dense(cfg, p, b["tokens"],
                                                 length=b.get("length")),
            decode=lambda p, c, t, pos: T.decode_dense(cfg, p, c, t, pos),
            cache_shapes=cs,
            empty_state=_zeros_empty_state(cs),
        )

    if fam == "moe":
        cs = lambda batch, max_len, **kw: _attn_cache_shapes(  # noqa: E731
            cfg, cfg.n_layers, batch, max_len)
        return Model(
            cfg=cfg,
            init=lambda rng: M.init_moe(cfg, rng),
            forward=lambda p, b: M.forward_moe(cfg, p, b["tokens"], parallel),
            prefill=lambda p, b: M.prefill_moe(cfg, p, b["tokens"], parallel,
                                               length=b.get("length")),
            decode=lambda p, c, t, pos: M.decode_moe(cfg, p, c, t, pos,
                                                     parallel),
            cache_shapes=cs,
            empty_state=_zeros_empty_state(cs),
        )

    if fam == "hybrid":
        cs = lambda batch, max_len, **kw: S.zamba_cache_shapes(  # noqa: E731
            cfg, batch, max_len)
        return Model(
            cfg=cfg,
            init=lambda rng: S.init_zamba(cfg, rng),
            forward=lambda p, b: S.forward_zamba(cfg, p, b["tokens"]),
            prefill=lambda p, b: S.prefill_zamba(cfg, p, b["tokens"],
                                                 length=b.get("length")),
            decode=lambda p, c, t, pos: S.decode_zamba(cfg, p, c, t, pos),
            cache_shapes=cs,
            empty_state=_zeros_empty_state(cs),
        )

    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=lambda rng: X.init_xlstm(cfg, rng),
            forward=lambda p, b: X.forward_xlstm(cfg, p, b["tokens"]),
            prefill=lambda p, b: X.prefill_xlstm(cfg, p, b["tokens"],
                                                 length=b.get("length")),
            decode=lambda p, c, t, pos: X.decode_xlstm(cfg, p, c, t, pos),
            cache_shapes=lambda batch, max_len, **kw: X.xlstm_cache_shapes(
                cfg, batch, max_len),
            empty_state=lambda batch, max_len, **kw: X.xlstm_empty_state(
                cfg, batch),
        )

    if fam == "audio":
        def cache_shapes(batch, max_len, enc_len=None, **kw):
            enc_len = enc_len or max_len
            c = _attn_cache_shapes(cfg, cfg.n_layers, batch, max_len)
            xsh = (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
            dtype = jnp.dtype(cfg.dtype)
            c["xk"] = jax.ShapeDtypeStruct(xsh, dtype)
            c["xv"] = jax.ShapeDtypeStruct(xsh, dtype)
            # per-sequence true encoder length: cross-attention masks
            # padded encoder rows by it (a batch-indexed state leaf, so
            # the serving engine threads it per slot like any O(1) state)
            c["enc_len"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
            return c
        return Model(
            cfg=cfg,
            init=lambda rng: T.init_audio(cfg, rng),
            forward=lambda p, b: T.forward_audio(cfg, p, b["tokens"],
                                                 b["frames"]),
            prefill=lambda p, b: T.prefill_audio(cfg, p, b["tokens"],
                                                 b["frames"],
                                                 length=b.get("length")),
            decode=lambda p, c, t, pos: T.decode_audio(cfg, p, c, t, pos),
            cache_shapes=cache_shapes,
            empty_state=_zeros_empty_state(cache_shapes),
        )

    if fam == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self_per = cfg.cross_attn_every - 1

        def cache_shapes(batch, max_len, **kw):
            dtype = jnp.dtype(cfg.dtype)
            sh = (n_cross, n_self_per, batch, max_len, cfg.n_kv_heads,
                  cfg.head_dim)
            xsh = (n_cross, batch, cfg.n_image_tokens, cfg.n_kv_heads,
                   cfg.head_dim)
            return {"k": jax.ShapeDtypeStruct(sh, dtype),
                    "v": jax.ShapeDtypeStruct(sh, dtype),
                    "xk": jax.ShapeDtypeStruct(xsh, dtype),
                    "xv": jax.ShapeDtypeStruct(xsh, dtype)}
        vlm_cs = cache_shapes
        return Model(
            cfg=cfg,
            init=lambda rng: T.init_vlm(cfg, rng),
            forward=lambda p, b: T.forward_vlm(cfg, p, b["tokens"],
                                               b["image_embeds"]),
            prefill=lambda p, b: T.prefill_vlm(cfg, p, b["tokens"],
                                               b["image_embeds"],
                                               length=b.get("length")),
            decode=lambda p, c, t, pos: T.decode_vlm(cfg, p, c, t, pos),
            cache_shapes=vlm_cs,
            empty_state=_zeros_empty_state(vlm_cs),
        )

    raise ValueError(f"unknown family {fam!r}")


def make_batch(cfg: ArchConfig, rng, batch: int, seq: int,
               with_targets: bool = True) -> Batch:
    """Random batch for smoke tests / examples (concrete arrays)."""
    k1, k2, k3 = jax.random.split(rng, 3)
    b: Batch = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab)}
    if with_targets:
        b["targets"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(k3, (batch, seq, cfg.d_model),
                                        jnp.float32).astype(cfg.dtype)
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            k3, (batch, cfg.n_image_tokens, cfg.d_model),
            jnp.float32).astype(cfg.dtype)
    return b
