"""Checkpointing: atomic, content-hashed pytree save/restore.

Used by (a) the training loop for checkpoint/restart fault tolerance, and
(b) the model repository — loading a serving variant is the same restore
path. Arrays are stored in an .npz plus a JSON manifest carrying the tree
structure and SHA-256 content hashes; writes are atomic (tmp + rename) so a
crash mid-write never corrupts the latest checkpoint.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save_pytree(path: str, tree: Any) -> Dict[str, str]:
    """Atomic save. Returns {leaf_path: sha256}."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves = _flatten_with_paths(tree)
    hashes = {k: hashlib.sha256(v.tobytes()).hexdigest() for k, v in leaves}
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "treedef": str(treedef),
        "leaves": [{"key": k, "shape": list(v.shape), "dtype": str(v.dtype),
                    "sha256": hashes[k]} for k, v in leaves],
    }
    tmpdir = tempfile.mkdtemp(dir=os.path.dirname(path) or ".")
    try:
        np.savez(os.path.join(tmpdir, "arrays.npz"),
                 **{k: v for k, v in leaves})
        with open(os.path.join(tmpdir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.replace(tmpdir, path)
    except BaseException:
        shutil.rmtree(tmpdir, ignore_errors=True)
        raise
    return hashes


def load_pytree(path: str, like: Optional[Any] = None,
                verify: bool = True) -> Any:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = []
    for entry in manifest["leaves"]:
        arr = data[entry["key"]]
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            if h != entry["sha256"]:
                raise IOError(
                    f"checkpoint corruption in {path}: leaf {entry['key']}")
        leaves.append(arr)
    if like is not None:
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)
    # rebuild as nested dict from the flat keys
    out: Dict[str, Any] = {}
    for entry, arr in zip(manifest["leaves"], leaves):
        node = out
        parts = entry["key"].split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out


class CheckpointManager:
    """Step-indexed checkpoints with retention; restores the latest intact
    checkpoint after a crash (restart path of the train loop)."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def save(self, step: int, tree: Any) -> None:
        save_pytree(self._dir(step), tree)
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.root, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[int, Any]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return step, load_pytree(self._dir(step), like=like)
