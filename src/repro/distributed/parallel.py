"""Parallelism context passed to model builders, plus the activation-
sharding hint consulted by the layer library (contextvar so host-side tests
and single-device runs are unaffected)."""
from __future__ import annotations

import contextvars
import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    mesh: object                     # jax.sharding.Mesh
    data_axes: Tuple[str, ...]       # ("pod", "data") or ("data",)
    model_axis: str = "model"
    moe_impl: str = "ep"             # "ep" (shard_map all_to_all) | "dense"

    @property
    def data_size(self) -> int:
        return int(
            __import__("numpy").prod([self.mesh.shape[a]
                                      for a in self.data_axes]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])


@dataclasses.dataclass(frozen=True)
class ActivationSharding:
    """Hints for with_sharding_constraint inside layer code: batch dims on
    the data axes, sequence dim on the model axis (sequence parallelism for
    the remat-saved residual stream). Carries the mesh so layer code can
    open shard_map regions (flash-decode split-K)."""
    data_axes: Tuple[str, ...]
    model_axis: str
    data_size: int
    model_size: int
    mesh: object = None

    def batch(self, n: int):
        return self.data_axes if n % self.data_size == 0 else None

    def seq(self, n: int):
        return self.model_axis if (n > 1 and n % self.model_size == 0) \
            else None


_ACT_CTX: contextvars.ContextVar[Optional[ActivationSharding]] = \
    contextvars.ContextVar("repro_activation_sharding", default=None)


def set_activation_sharding(ctx: Optional[ActivationSharding]):
    return _ACT_CTX.set(ctx)


def get_activation_sharding() -> Optional[ActivationSharding]:
    return _ACT_CTX.get()


def activation_sharding_from(parallel: "ParallelConfig") -> ActivationSharding:
    return ActivationSharding(
        data_axes=parallel.data_axes, model_axis=parallel.model_axis,
        data_size=parallel.data_size, model_size=parallel.model_size,
        mesh=parallel.mesh)
