"""Partition specs for every architecture family x entry point.

Rules (baseline; §Perf iterates from here):
  * batch dims -> the data axes ("pod","data" multi-pod / "data" single-pod),
    only when divisible (long_500k has batch 1 -> replicated).
  * attention heads -> "model": KV-head dim when it divides the axis, else
    the q-per-kv group dim, else fall back to row-parallel d_model.
  * MLP hidden -> "model" (column-parallel in, row-parallel out).
  * MoE experts -> "model" (expert parallelism; the shard_map all_to_all
    path in models/moe.py matches these specs).
  * Mamba/xLSTM inner dims -> "model" head-aligned (see models/ssm.py note).
  * KV caches: KV-head dim when divisible, else the sequence dim ->
    "model" (split-K decode; keeps the 32k-524k caches within HBM).

Every function mirrors the corresponding init structure in repro.models and
is locked by tests/test_sharding.py tree-structure checks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_lib


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: Tuple[str, ...]
    model: str
    data_size: int
    model_size: int

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        model = "model" if "model" in names else names[-1]
        data = tuple(n for n in names if n != model)
        dsize = int(np.prod([mesh.shape[a] for a in data])) if data else 1
        return cls(data=data, model=model, data_size=dsize,
                   model_size=int(mesh.shape[model]))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _dax(ax: MeshAxes, n: int):
    return ax.data if _div(n, ax.data_size) else None


def _max(ax: MeshAxes, n: int):
    return ax.model if _div(n, ax.model_size) else None


# ---------------------------------------------------------------------------
# attention / mlp block specs


def needs_fsdp(cfg: ArchConfig, ax: MeshAxes) -> bool:
    """Model-axis sharding alone must leave params under ~4 GiB/device;
    beyond that, weights are additionally sharded over the data axes
    (ZeRO-3 style; GSPMD inserts the per-layer all-gathers)."""
    per_dev = cfg.param_count() * 2.0 / max(ax.model_size, 1)
    return per_dev > 4 * 2**30


def _attn_specs(cfg: ArchConfig, ax: MeshAxes, stacked: bool = True,
                fsdp: bool = False):
    m = ax.model
    K, G = cfg.n_kv_heads, cfg.q_per_kv
    pre = (None,) if stacked else ()
    dd = _dax(ax, cfg.d_model) if fsdp else None      # fsdp axis on d_model
    dh = _dax(ax, cfg.head_dim) if fsdp else None     # fsdp axis on head_dim
    if _div(K, ax.model_size):
        wq = P(*pre, dd, m, None, None)
        wk = P(*pre, dd, m, None)
        wo = P(*pre, m, None, None, dd)
    elif _div(G, ax.model_size):
        wq = P(*pre, dd, None, m, None)
        wk = P(*pre, dd, None, None)         # kv replicated over model
        wo = P(*pre, None, m, None, dd)
    else:                                    # row-parallel fallback on d
        wq = P(*pre, m, None, None, dh)
        wk = P(*pre, m, None, dh)
        wo = P(*pre, None, None, dh, m)
    return {"wq": wq, "wk": wk, "wv": wk, "wo": wo}


def _mlp_specs(cfg: ArchConfig, ax: MeshAxes, d_ff: Optional[int] = None,
               stacked: bool = True, fsdp: bool = False):
    m_ff = _max(ax, d_ff if d_ff is not None else cfg.d_ff)
    dd = _dax(ax, cfg.d_model) if fsdp else None
    pre = (None,) if stacked else ()
    return {"w_gate": P(*pre, dd, m_ff),
            "w_up": P(*pre, dd, m_ff),
            "w_down": P(*pre, m_ff, dd)}


def _block_specs(cfg: ArchConfig, ax: MeshAxes, d_ff: Optional[int] = None,
                 fsdp: bool = False):
    return {"attn": _attn_specs(cfg, ax, fsdp=fsdp),
            "mlp": _mlp_specs(cfg, ax, d_ff, fsdp=fsdp),
            "ln1": P(None, None), "ln2": P(None, None)}


def _embed_spec(cfg: ArchConfig, ax: MeshAxes, fsdp: bool = False):
    dd = _dax(ax, cfg.d_model) if fsdp else None
    return P(_max(ax, cfg.vocab), dd)


# ---------------------------------------------------------------------------
# per-family parameter specs


def param_specs(cfg: ArchConfig, ax: MeshAxes,
                fsdp: Optional[bool] = None) -> Any:
    fam = cfg.family
    fsdp = needs_fsdp(cfg, ax) if fsdp is None else fsdp
    if fam == "dense":
        return {"embed": _embed_spec(cfg, ax, fsdp),
                "layers": _block_specs(cfg, ax, fsdp=fsdp),
                "ln_f": P(None), "head": _embed_spec(cfg, ax, fsdp)}
    if fam == "moe":
        m = ax.model
        fe = _dax(ax, cfg.d_ff) if fsdp else None
        moe = {"router": P(None, None, None),
               "w_gate": P(None, m, None, fe),
               "w_up": P(None, m, None, fe),
               "w_down": P(None, m, fe, None)}
        if cfg.n_shared_experts:
            moe["shared"] = _mlp_specs(
                cfg, ax, d_ff=cfg.d_ff * cfg.n_shared_experts, fsdp=fsdp)
        return {"embed": _embed_spec(cfg, ax, fsdp),
                "layers": {"attn": _attn_specs(cfg, ax, fsdp=fsdp),
                           "moe": moe,
                           "ln1": P(None, None), "ln2": P(None, None)},
                "ln_f": P(None), "head": _embed_spec(cfg, ax, fsdp)}
    if fam == "hybrid":
        di, h, pdim, ci = ssm_lib.mamba_dims(cfg)
        m_di = _max(ax, di)
        m_h = _max(ax, h)
        mamba = {
            "w_z": P(None, None, m_di), "w_x": P(None, None, m_di),
            "w_bc": P(None, None, None), "w_dt": P(None, None, m_h),
            "conv_x_w": P(None, None, m_di), "conv_x_b": P(None, m_di),
            "conv_bc_w": P(None, None, None), "conv_bc_b": P(None, None),
            "A_log": P(None, m_h), "D": P(None, m_h),
            "dt_bias": P(None, m_h), "norm": P(None, m_di),
            "out_proj": P(None, m_di, None),
        }
        shared = {"attn": _attn_specs(cfg, ax, stacked=False, fsdp=fsdp),
                  "mlp": _mlp_specs(cfg, ax, stacked=False, fsdp=fsdp),
                  "ln1": P(None), "ln2": P(None)}
        return {"embed": _embed_spec(cfg, ax), "mamba": mamba,
                "shared": shared, "ln_f": P(None),
                "head": _embed_spec(cfg, ax)}
    if fam == "ssm":
        d = cfg.d_model
        m_d = _max(ax, d)
        m_2d = _max(ax, 2 * d)
        f_ff = max(128, int(d * 4 / 3) // 64 * 64)
        mlstm = {"w_up": P(None, None, m_2d),
                 "wq": P(None, None, m_d), "wk": P(None, None, m_d),
                 "wv": P(None, None, m_d),
                 "w_gate": P(None, None, None),
                 "gate_bias": P(None, None),
                 "w_down": P(None, m_d, None),
                 "ln": P(None, None)}
        hd = cfg.head_dim
        slstm = {"w_in": P(None, None, _max(ax, 4 * d)),
                 "r": P(None, None, None, None, _max(ax, hd)),
                 "bias": P(None, None),
                 "ln": P(None, None), "ln2": P(None, None),
                 "ffn": _mlp_specs(cfg, ax, d_ff=f_ff)}
        return {"embed": _embed_spec(cfg, ax), "mlstm": mlstm,
                "slstm": slstm, "ln_f": P(None),
                "head": _embed_spec(cfg, ax)}
    if fam == "audio":
        return {"embed": _embed_spec(cfg, ax, fsdp),
                "encoder": _block_specs(cfg, ax, fsdp=fsdp),
                "decoder": _block_specs(cfg, ax, fsdp=fsdp),
                "cross": _block_specs(cfg, ax, fsdp=fsdp),
                "ln_f": P(None), "head": _embed_spec(cfg, ax, fsdp)}
    if fam == "vlm":
        return {"embed": _embed_spec(cfg, ax, fsdp),
                "layers": _block_specs(cfg, ax, fsdp=fsdp),
                "cross_layers": _block_specs(cfg, ax, fsdp=fsdp),
                "ln_f": P(None), "head": _embed_spec(cfg, ax, fsdp)}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# batch / cache specs


def batch_specs(cfg: ArchConfig, batch: int, ax: MeshAxes,
                with_targets: bool = True) -> Any:
    dax = _dax(ax, batch)
    out = {"tokens": P(dax, None)}
    if with_targets:
        out["targets"] = P(dax, None)
    if cfg.family == "audio":
        out["frames"] = P(dax, None, None)
    if cfg.family == "vlm":
        out["image_embeds"] = P(dax, None, None)
    return out


def _kv_spec(cfg: ArchConfig, ax: MeshAxes, batch: int, n_lead: int = 1):
    """(lead..., B, S, K, D): KV-head sharding when divisible, else split-K
    over the sequence dim."""
    dax = _dax(ax, batch)
    lead = (None,) * n_lead
    if _div(cfg.n_kv_heads, ax.model_size):
        return P(*lead, dax, None, ax.model, None)
    return P(*lead, dax, ax.model, None, None)


def cache_specs(cfg: ArchConfig, batch: int, ax: MeshAxes) -> Any:
    fam = cfg.family
    dax = _dax(ax, batch)
    if fam in ("dense", "moe"):
        kv = _kv_spec(cfg, ax, batch)
        return {"k": kv, "v": kv}
    if fam == "audio":
        kv = _kv_spec(cfg, ax, batch)
        # enc_len: per-sequence true encoder length (B,) — batch-sharded
        return {"k": kv, "v": kv, "xk": kv, "xv": kv,
                "enc_len": P(_dax(ax, batch))}
    if fam == "vlm":
        kv = _kv_spec(cfg, ax, batch, n_lead=2)
        # image-token dim (1601) does not divide the mesh: shard KV heads if
        # possible, else replicate over model (it is small)
        xkv = P(None, dax, None,
                ax.model if _div(cfg.n_kv_heads, ax.model_size) else None,
                None)
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}
    if fam == "hybrid":
        di, h, pdim, ci = ssm_lib.mamba_dims(cfg)
        kv = _kv_spec(cfg, ax, batch)
        return {"ssm": P(None, dax, _max(ax, h), None, None),
                "conv": P(None, dax, None, None),
                "k": kv, "v": kv}
    if fam == "ssm":
        hd = cfg.head_dim
        return {"mC": P(None, dax, None, _max(ax, hd), None),
                "mn": P(None, dax, None, _max(ax, hd)),
                "mm": P(None, dax, None),
                "sh": P(None, dax, _max(ax, cfg.d_model)),
                "sc": P(None, dax, _max(ax, cfg.d_model)),
                "sn": P(None, dax, _max(ax, cfg.d_model)),
                "sm": P(None, dax, _max(ax, cfg.d_model))}
    raise ValueError(fam)


def opt_state_specs(pspecs: Any) -> Any:
    """AdamW moments mirror the param specs; step is replicated."""
    from repro.training.optimizer import AdamWState
    return AdamWState(step=P(), mu=pspecs, nu=pspecs)


def train_state_specs(cfg: ArchConfig, ax: MeshAxes) -> Any:
    ps = param_specs(cfg, ax)
    return {"params": ps, "opt": opt_state_specs(ps), "rng": P()}


def to_named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
