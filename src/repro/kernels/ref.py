"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references swept against the kernels in
``tests/test_kernels_*.py`` (interpret mode) and the XLA fallback used by the
models on non-TPU backends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        q_offset: int = 0,
                        kv_valid_len: Optional[int] = None) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, K, T, D) with H = K * G (GQA).

    Returns (B, H, S, D). Softmax in f32.
    """
    B, H, S, D = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, S, D)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * (D ** -0.5)
    T = k.shape[2]
    t_idx = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        s_idx = jnp.arange(S)[:, None] + q_offset
        mask = t_idx[None, :] <= s_idx
    if kv_valid_len is not None:
        mask = mask & (t_idx[None, :] < kv_valid_len)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v)
    return out.reshape(B, H, S, D)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid_len) -> jax.Array:
    """One-token decode. q: (B, K, G, D); k/v: (B, K, T, D); valid_len scalar.

    Returns (B, K, G, D).
    """
    B, K, G, D = q.shape
    T = k.shape[2]
    scores = jnp.einsum("bkgd,bktd->bkgt", q, k,
                        preferred_element_type=jnp.float32) * (D ** -0.5)
    mask = jnp.arange(T)[None, None, None, :] < valid_len
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgt,bktd->bkgd", probs, v)


def int8_matmul_ref(x: jax.Array, w_q: jax.Array,
                    scales: jax.Array) -> jax.Array:
    """x: (M, Kd) bf16/f32; w_q: (Kd, N) int8; scales: (N,) per-channel f32.

    Returns (M, N) in x.dtype; dequantized weight = w_q * scales.
    """
    w = w_q.astype(jnp.float32) * scales[None, :].astype(jnp.float32)
    out = jnp.einsum("mk,kn->mn", x.astype(jnp.float32), w)
    return out.astype(x.dtype)


def quantize_int8(w: jax.Array):
    """Per-output-channel symmetric int8 quantization. w: (Kd, N)."""
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)
    scales = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    w_q = jnp.clip(jnp.round(w.astype(jnp.float32) / scales[None, :]),
                   -127, 127).astype(jnp.int8)
    return w_q, scales
