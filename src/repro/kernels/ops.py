"""Jit'd public wrappers around the Pallas kernels.

Dispatch: on TPU the Mosaic kernels run natively; elsewhere callers request
``interpret=True`` (kernel body executed in Python on CPU) or fall back to the
``ref`` oracles (pure XLA). The model layer (``ArchConfig.attention_impl``)
selects among "xla" | "pallas" | "pallas_interpret".
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.int8_matmul import int8_matmul


def flash_attention_grouped(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True, q_offset=0,
                            kv_valid_len=None,
                            interpret: bool = False) -> jax.Array:
    """Adapter from the model layout to the kernel layout.

    q: (B, S, K, G, D); k/v: (B, T, K, D). Returns (B, S, K, G, D).
    """
    B, S, K, G, D = q.shape
    T = k.shape[1]
    kt = k.transpose(0, 2, 1, 3)    # (B, K, T, D)
    vt = v.transpose(0, 2, 1, 3)
    if S == 1:
        # decode shape -> flash-decode kernel
        qd = q[:, 0]                # (B, K, G, D)
        vlen = kv_valid_len if kv_valid_len is not None else T
        out = decode_attention(qd, kt, vt, valid_len=vlen,
                               interpret=interpret)
        return out[:, None].reshape(B, 1, K, G, D)
    qh = q.transpose(0, 2, 3, 1, 4).reshape(B, K * G, S, D)
    out = flash_attention(qh, kt, vt, valid_len=kv_valid_len, causal=causal,
                          q_offset=int(q_offset) if not hasattr(
                              q_offset, "dtype") else q_offset,
                          interpret=interpret)
    return out.reshape(B, K, G, S, D).transpose(0, 3, 1, 2, 4)


__all__ = ["flash_attention", "decode_attention", "int8_matmul",
           "flash_attention_grouped", "ref"]
