"""Weight-only int8 dequant matmul, Pallas TPU.

The TPU analogue of the paper's TensorRT mixed-precision variant generation:
INFaaS's profiler emits int8 weight-only variants of every registered model;
this kernel is their GEMM. Weights stream from HBM as int8 (2x less traffic
than bf16 — the dominant term for small-batch serving GEMMs), are dequantized
in VMEM with per-output-channel scales, and accumulate in f32.

Grid = (n_m, n_n, n_k), K innermost with an f32 accumulator scratch revisited
across K steps. Blocks default to (128, 128, 256) — MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 256


def _int8_mm_kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, n_k_blocks: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)               # (bm, bk)
    w = w_ref[...].astype(jnp.float32)               # (bk, bn) dequant below
    acc_scr[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(kk == n_k_blocks - 1)
    def _finish():
        scales = s_ref[...].astype(jnp.float32)      # (1, bn)
        o_ref[...] = (acc_scr[...] * scales).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def int8_matmul(x: jax.Array, w_q: jax.Array, scales: jax.Array, *,
                block_m: int = DEFAULT_BLOCK_M,
                block_n: int = DEFAULT_BLOCK_N,
                block_k: int = DEFAULT_BLOCK_K,
                interpret: bool = False) -> jax.Array:
    """x: (M, Kd); w_q: (Kd, N) int8; scales: (N,) f32. Returns (M, N).

    Per-output-channel symmetric dequant is folded into the epilogue:
    (x @ w_q) * scales == x @ (w_q * scales).
    """
    M, Kd = x.shape
    N = w_q.shape[1]
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, Kd)
    assert M % block_m == 0 and N % block_n == 0 and Kd % block_k == 0
    grid = (M // block_m, N // block_n, Kd // block_k)

    kernel = functools.partial(_int8_mm_kernel, n_k_blocks=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, w_q, scales.reshape(1, N))
