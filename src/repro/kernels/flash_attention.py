"""Causal GQA flash attention (prefill/train), Pallas TPU.

Online-softmax over KV blocks. Grid = (B, H, n_q_blocks, n_kv_blocks) with
the KV dimension innermost: the output block (block_q, D) is revisited across
KV steps, and running (m, l, acc) live in VMEM scratch. Block dims default to
(128, 128) — MXU-aligned. GQA is handled by the K/V index maps (kv head =
q head // group size), so KV is never repeated in memory.

VMEM working set per step (defaults, D=128, f32 scratch):
  q (128x128 bf16) + k,v (128x128 bf16 each) + acc/m/l f32 ~ 0.2 MB << 16 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  causal: bool, q_offset: int, block_q: int, block_k: int,
                  n_kv_blocks: int, sm_scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset
    k_start = kj * block_k
    valid_len = vlen_ref[0]

    # block-level skip: strictly above the causal diagonal or fully invalid
    run = k_start < valid_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        t_idx = k_start + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = t_idx < valid_len
        if causal:
            s_idx = q_start + lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            mask = jnp.logical_and(mask, t_idx <= s_idx)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finish():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / lsum).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    valid_len: Optional[jax.Array] = None, *,
                    causal: bool = True, q_offset: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, K, T, D), H = K*G. Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    _, K, T, _ = k.shape
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    nq, nk = S // block_q, T // block_k
    if valid_len is None:
        valid_len = jnp.array([T], jnp.int32)
    else:
        valid_len = jnp.asarray(valid_len, jnp.int32).reshape(1)

    kernel = functools.partial(
        _flash_kernel, causal=causal, q_offset=q_offset, block_q=block_q,
        block_k=block_k, n_kv_blocks=nk, sm_scale=D ** -0.5)

    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D),
                             lambda b, h, i, j, vlen: (b, h, i, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, i, j, vlen: (b, h // G, j, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, i, j, vlen: (b, h // G, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, D),
                                   lambda b, h, i, j, vlen: (b, h, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(valid_len, q, k, v)
