"""Flash-decode (split-K) GQA attention for one-token serving, Pallas TPU.

One query token attends to a long KV cache. Grid = (B, K, n_t_blocks): the KV
sequence is tiled; each step computes a partial online-softmax update for all
G query heads sharing the KV head, with running (m, l, acc) in VMEM scratch.
This is the TPU analogue of FlashDecoding's split-K: HBM traffic is exactly
one pass over the KV cache, the dominant term for decode at 32k-524k context.

The G dimension (q heads per KV head) rides inside the block as the row dim
of a (G, block_t) score matrix, so the MXU sees (G x D) @ (D x block_t).

``paged_decode_attention`` is the block-table variant for the paged KV
layout (``repro.models.kvcache``): the KV tile for grid step ``j`` of slot
``b`` is page ``block_table[b, j]`` of a shared (n_pages, page_size, K, D)
pool, resolved in the BlockSpec index map from a scalar-prefetched block
table — the page indirection costs no extra HBM pass, and per-slot valid
lengths ride in a second prefetched scalar.

``fused_paged_decode_attention`` additionally folds the token's KV *write*
into the same kernel: the new k/v row is injected into the write page's
tile in VMEM before the scores are computed, and the updated page is
flushed back through an aliased pool output — the separate per-step XLA
pool scatter (and its read-modify-write pass over the pool) disappears
from the decode loop. The pool output's BlockSpec pins every grid step of
a (slot, head) pair to that slot's single write page, so exactly one
store (at the write page's logical block) defines the flushed content.
Safety relies on two invariants the serving engine maintains: a written
page is private to its slot (copy-on-write guarantees refcount 1), and
the pool carries one extra *trash page* at index ``n_pages - 1`` — equal
to the block table's sentinel value — so writes by inactive slots land
harmlessly in a page no block table references for live reads (stale
trash contents sit behind ``valid_len`` and mask to exact zeros).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 512
NEG_INF = -1e30


def _decode_kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   block_t: int, n_t_blocks: int, sm_scale: float):
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    t_start = tj * block_t
    valid_len = vlen_ref[0]

    @pl.when(t_start < valid_len)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bt, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bt, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        t_idx = t_start + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(t_idx < valid_len, s, NEG_INF)  # (G, bt)
        m_prev = m_scr[...]                          # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(tj == n_t_blocks - 1)
    def _finish():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / lsum).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: Optional[jax.Array] = None, *,
                     block_t: int = DEFAULT_BLOCK_T,
                     interpret: bool = False) -> jax.Array:
    """q: (B, K, G, D); k/v: (B, K, T, D); valid_len scalar (<= T).

    Returns (B, K, G, D).
    """
    B, K, G, D = q.shape
    T = k.shape[2]
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)
    nt = T // block_t
    if valid_len is None:
        valid_len = jnp.array([T], jnp.int32)
    else:
        valid_len = jnp.asarray(valid_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, block_t=block_t,
                               n_t_blocks=nt, sm_scale=D ** -0.5)
    grid = (B, K, nt)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, j, vlen: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_t, D),
                             lambda b, h, j, vlen: (b, h, j, 0)),
                pl.BlockSpec((1, 1, block_t, D),
                             lambda b, h, j, vlen: (b, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, j, vlen: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(valid_len, q, k, v)


def _paged_decode_kernel(vlen_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *,
                         page_size: int, n_t_blocks: int, sm_scale: float):
    b = pl.program_id(0)
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    t_start = tj * page_size
    valid_len = vlen_ref[b]

    @pl.when(t_start < valid_len)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)       # (ps, D)
        v = v_ref[0, :, 0].astype(jnp.float32)       # (ps, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        t_idx = t_start + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(t_idx < valid_len, s, NEG_INF)  # (G, ps)
        m_prev = m_scr[...]                          # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(tj == n_t_blocks - 1)
    def _finish():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / lsum).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_table: jax.Array,
                           valid_len: Optional[jax.Array] = None, *,
                           interpret: bool = False) -> jax.Array:
    """Flash-decode through a block table over a shared page pool.

    q: (B, K, G, D); k_pool/v_pool: (n_pages, page_size, K, D) — the paged
    cache layout of ``repro.models.kvcache``; block_table: (B, P) page ids
    (sentinel entries >= n_pages clamp to the last page and are masked by
    ``valid_len``); valid_len: scalar or (B,) per-slot valid lengths over
    the slot's *logical* sequence of P * page_size positions.

    Returns (B, K, G, D).
    """
    B, K, G, D = q.shape
    n_pages, page_size = k_pool.shape[:2]
    P = block_table.shape[1]
    if valid_len is None:
        valid_len = jnp.full((B,), P * page_size, jnp.int32)
    else:
        valid_len = jnp.broadcast_to(
            jnp.asarray(valid_len, jnp.int32), (B,))
    bt = jnp.clip(block_table.astype(jnp.int32), 0, n_pages - 1)

    kernel = functools.partial(_paged_decode_kernel, page_size=page_size,
                               n_t_blocks=P, sm_scale=D ** -0.5)
    grid = (B, K, P)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, j, vlen, bt: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, vlen, bt: (bt[b, j], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, vlen, bt: (bt[b, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, j, vlen, bt: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(valid_len, bt, q, k_pool, v_pool)


def _fused_paged_decode_kernel(vlen_ref, wblk_ref, woff_ref, bt_ref,
                               q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
                               o_ref, ko_ref, vo_ref,
                               m_scr, l_scr, acc_scr, *,
                               page_size: int, n_t_blocks: int,
                               sm_scale: float):
    b = pl.program_id(0)
    tj = pl.program_id(2)

    @pl.when(tj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    t_start = tj * page_size
    valid_len = vlen_ref[b]
    is_w = tj == wblk_ref[b]

    # Inject the new token's k/v row into this tile when it is the write
    # block, then attend over the *updated* tile: the row is visible to
    # the very score pass that needs it (valid_len == pos + 1 covers it)
    # without ever round-tripping HBM.
    sel = (lax.broadcasted_iota(jnp.int32, (page_size, 1), 0)
           == woff_ref[b]) & is_w
    k = jnp.where(sel, kn_ref[0, 0].astype(jnp.float32),
                  kp_ref[0, :, 0].astype(jnp.float32))     # (ps, D)
    v = jnp.where(sel, vn_ref[0, 0].astype(jnp.float32),
                  vp_ref[0, :, 0].astype(jnp.float32))

    @pl.when(is_w)
    def _flush():
        # the pool outputs' index maps pin every j of this (b, h) to the
        # write page, so this single store is what the one flush carries
        ko_ref[0, :, 0] = k.astype(ko_ref.dtype)
        vo_ref[0, :, 0] = v.astype(vo_ref.dtype)

    @pl.when(t_start < valid_len)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        t_idx = t_start + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(t_idx < valid_len, s, NEG_INF)  # (G, ps)
        m_prev = m_scr[...]                          # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(tj == n_t_blocks - 1)
    def _finish():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / lsum).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_paged_decode_attention(q: jax.Array, k_new: jax.Array,
                                 v_new: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, block_table: jax.Array,
                                 pos: jax.Array, *, interpret: bool = False):
    """One-token paged decode with the KV write fused into the kernel.

    q: (B, K, G, D); k_new/v_new: (B, K, D) — the token's fresh k/v rows;
    k_pool/v_pool: (n_phys, page_size, K, D); block_table: (B, P);
    pos: scalar or (B,) — the position being written (and attended up to,
    inclusive: valid length is ``pos + 1``).

    **Pool contract** (the serving engine's pallas-paged layout): the pool
    carries one trash page at the top, ``n_phys == sentinel + 1`` with
    every sentinel block-table entry equal to ``n_phys - 1``, so inactive
    slots' writes land in the trash page instead of needing per-slot
    write suppression; and a written page is referenced by exactly one
    slot (the engine copies shared pages on write).

    Returns ``(out, k_pool', v_pool')`` with ``out``: (B, K, G, D); the
    pools are updated in place (aliased).
    """
    B, K, G, D = q.shape
    n_phys, page_size = k_pool.shape[:2]
    P = block_table.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    vlen = pos + 1
    wblk = jnp.clip(pos // page_size, 0, P - 1)
    woff = pos % page_size
    bt = jnp.clip(block_table.astype(jnp.int32), 0, n_phys - 1)
    kn = k_new.reshape(B, K, 1, D)
    vn = v_new.reshape(B, K, 1, D)

    kernel = functools.partial(_fused_paged_decode_kernel,
                               page_size=page_size, n_t_blocks=P,
                               sm_scale=D ** -0.5)
    grid = (B, K, P)
    out_shapes = (
        jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
        jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, j, vl, wb, wo, bt: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, 1, D),
                             lambda b, h, j, vl, wb, wo, bt: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, 1, D),
                             lambda b, h, j, vl, wb, wo, bt: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, vl, wb, wo, bt:
                             (bt[b, j], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, vl, wb, wo, bt:
                             (bt[b, j], 0, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, j, vl, wb, wo, bt: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, vl, wb, wo, bt:
                             (bt[b, wb[b]], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, vl, wb, wo, bt:
                             (bt[b, wb[b]], 0, h, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=out_shapes,
        # pools are donated: inputs 7/8 of (vlen, wblk, woff, bt, q, kn,
        # vn, k_pool, v_pool) become outputs 1/2 — the kernel rewrites
        # only each slot's private write page (plus the trash page)
        input_output_aliases={7: 1, 8: 2},
        interpret=interpret,
    )(vlen, wblk, woff, bt, q, kn, vn, k_pool, v_pool)
