"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks. [arXiv:2411.15242; hf]

38 Mamba2 (SSD) layers; one *shared* transformer block (attention + SwiGLU,
same parameters each invocation) applied every ``shared_attn_every`` layers,
faithful to the Zamba2 design. Sub-quadratic -> runs ``long_500k``.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_000,
    head_dim=64,
    ssm_state=64,
    ssm_chunk=256,
    shared_attn_every=6,
    subquadratic=True,
    source="arXiv:2411.15242",
)
