"""llama-3.2-vision-90b [vlm] — cross-attn image layers, backbone only.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB: ``input_specs()`` provides precomputed patch
embeddings (B, n_image_tokens, d_model). Every 10th decoder layer is a
cross-attention layer over the patch embeddings (10 cross layers for 100L).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab=128_256,
    head_dim=128,
    cross_attn_every=10,
    n_image_tokens=1601,     # one 560x560 tile + CLS, llama3.2-vision default
    subquadratic=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
