"""Architecture configuration system.

Every assigned architecture is expressed as an ``ArchConfig``. A config is pure
data: the model builders in ``repro.models`` consume it, the profiler generates
variants from it, and the dry-run lowers it. ``reduced()`` produces a tiny
same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# Families understood by the model builder.
FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static architecture description (one per assigned architecture)."""

    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                        # for MoE: per-expert hidden size
    vocab: int

    # --- attention details ---
    head_dim: Optional[int] = None   # default: d_model // n_heads
    rope_theta: float = 500_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0               # Mamba2 state size N
    ssm_chunk: int = 256             # SSD chunk length
    shared_attn_every: int = 6       # zamba2: shared attention block period
    # --- xLSTM ---
    slstm_every: int = 4             # one sLSTM block per this many layers
    # --- audio (enc-dec) ---
    n_encoder_layers: int = 0
    # --- vlm ---
    cross_attn_every: int = 0        # 0 = no cross attention
    n_image_tokens: int = 0          # stub patch-embedding count
    # --- numerics / implementation ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    attention_impl: str = "xla"      # "xla" | "pallas" | "pallas_interpret"
    remat: bool = True
    # sub-quadratic sequence mixing? (gates long_500k applicability)
    subquadratic: bool = False
    # citation / provenance string
    source: str = ""

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # ------------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count N (total, incl. all experts)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        return _param_count(self, active_only=True)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 8),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=32,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            cross_attn_every=min(self.cross_attn_every, 2) if self.cross_attn_every else 0,
            n_image_tokens=min(self.n_image_tokens, 16) if self.n_image_tokens else 0,
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )


def _param_count(cfg: ArchConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    emb = cfg.vocab * d
    per_layer = 0
    # attention block (for families that have it on every layer)
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    ffn_dense = 3 * d * cfg.d_ff  # SwiGLU: gate, up, down
    if cfg.family in ("dense", "vlm"):
        per_layer = attn + ffn_dense
        if cfg.family == "vlm" and cfg.cross_attn_every:
            n_cross = cfg.n_layers // cfg.cross_attn_every
            per_layer_total = cfg.n_layers * per_layer + n_cross * attn
            return emb * 2 + per_layer_total
    elif cfg.family == "moe":
        n_e = (cfg.top_k + cfg.n_shared_experts) if active_only else (
            cfg.n_experts + cfg.n_shared_experts)
        per_layer = attn + n_e * 3 * d * cfg.d_ff + d * cfg.n_experts  # + router
    elif cfg.family == "hybrid":
        # Mamba2 block params: in_proj (x, z, B, C, dt) + out_proj
        d_inner = 2 * d
        mamba = d * (2 * d_inner + 2 * cfg.ssm_state + cfg.n_heads) + d_inner * d
        shared = attn + ffn_dense  # one shared transformer block (counted once)
        return emb * 2 + cfg.n_layers * mamba + shared
    elif cfg.family == "ssm":
        # xLSTM: mLSTM block (qkv + gates + out) ~ 8 d^2 ; sLSTM ~ 4.3 d^2 + ffn
        m_blk = 8 * d * d
        s_blk = 5 * d * d
        n_s = cfg.n_layers // cfg.slstm_every
        return emb * 2 + (cfg.n_layers - n_s) * m_blk + n_s * s_blk
    elif cfg.family == "audio":
        enc = cfg.n_encoder_layers * (attn + ffn_dense)
        dec = cfg.n_layers * (2 * attn + ffn_dense)
        return emb * 2 + enc + dec
    return emb * 2 + cfg.n_layers * per_layer
