"""whisper-base [audio] — enc-dec transformer backbone, conv frontend STUB.
[arXiv:2212.04356; unverified]

``input_specs()`` provides precomputed frame embeddings (B, S, d_model); the
strided-conv mel frontend is a stub per the assignment. 6 encoder + 6 decoder
layers (decoder layers carry self- + cross-attention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,              # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    head_dim=64,
    subquadratic=False,
    source="arXiv:2212.04356",
)
