"""Assigned input-shape set (applies to every LM architecture).

Each shape names the entry point it lowers:
  * ``train_4k``    -> train_step   (training)
  * ``prefill_32k`` -> prefill_step (inference prefill, builds the cache)
  * ``decode_32k``  -> serve_step   (one new token, KV cache of seq_len)
  * ``long_500k``   -> serve_step   (sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def entry_point(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step",
                "decode": "serve_step"}[self.kind]


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; else reason for the skip.

    Per assignment: ``long_500k`` needs sub-quadratic attention -> skipped for
    pure full-attention archs; runs for SSM/hybrid/linear-attention archs.
    No encoder-only archs are assigned, so decode shapes always apply.
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch; 524k context requires "
                       "sub-quadratic sequence mixing (DESIGN.md §4)")
    return True, ""
