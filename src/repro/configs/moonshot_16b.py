"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,           # per-expert hidden size
    vocab=163_840,
    head_dim=128,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    subquadratic=False,
    source="hf:moonshotai/Moonlight-16B-A3B",
)
