from repro.configs.base import ArchConfig  # noqa: F401
from repro.configs.shapes import (ALL_SHAPES, SHAPES, ShapeConfig,  # noqa: F401
                                  shape_applicable)

__all__ = ["ArchConfig", "ShapeConfig", "ALL_SHAPES", "SHAPES",
           "shape_applicable", "ARCHS", "get_arch"]


def __getattr__(name):
    # lazy to avoid importing all config modules unless needed
    if name in ("ARCHS", "get_arch"):
        from repro.configs import registry
        return getattr(registry, name)
    raise AttributeError(name)
