"""Registry of all assigned architectures, selectable by ``--arch <id>``."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig
from repro.configs import (llama3_2_1b, minitron_8b, yi_9b, phi3_mini,
                           zamba2_1p2b, moonshot_16b, qwen3_moe_235b,
                           whisper_base, llama3_2_vision_90b, xlstm_1p3b)

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in (
        llama3_2_1b.CONFIG,
        minitron_8b.CONFIG,
        yi_9b.CONFIG,
        phi3_mini.CONFIG,
        zamba2_1p2b.CONFIG,
        moonshot_16b.CONFIG,
        qwen3_moe_235b.CONFIG,
        whisper_base.CONFIG,
        llama3_2_vision_90b.CONFIG,
        xlstm_1p3b.CONFIG,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choices: {sorted(ARCHS)}")
    return ARCHS[name]
