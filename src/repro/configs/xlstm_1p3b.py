"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

d_ff=0 per assignment: the up/down projections live inside the xLSTM blocks
(mLSTM proj factor 2, sLSTM gated FFN 4/3). One sLSTM block every
``slstm_every`` layers, the rest chunkwise-parallel mLSTM.
Sub-quadratic -> runs ``long_500k``.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    head_dim=512,
    slstm_every=4,
    ssm_chunk=256,
    subquadratic=True,
    source="arXiv:2405.04517",
)
