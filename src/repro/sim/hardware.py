"""Hardware catalog + roofline latency model (TPU v5e target).

The profiler derives per-variant latency curves from these specs; the
simulator executes against them; the roofline analysis (launch/roofline.py)
uses the same constants. Paper mapping (DESIGN.md §2): "hardware platform" =
host CPU or a TPU v5e slice shape; prices mirror the paper's >=6x GPU/CPU gap
in chip-second units.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# --- v5e chip constants (also used by §Roofline) ---
V5E_PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
V5E_HBM_BW = 819e9                    # B/s per chip
V5E_ICI_BW = 50e9                     # B/s per link
V5E_HBM_BYTES = 16 * 2**30
PCIE_LOAD_BW = 12e9                   # host->device weight-load bandwidth


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    kind: str                 # "cpu" | "accel"
    chips: int                # accelerator chips (0 for cpu)
    peak_flops: float         # FLOP/s (aggregate)
    mem_bw: float             # B/s (aggregate)
    mem_capacity: float       # bytes available for model weights + buffers
    load_bw: float            # B/s for loading weights from the repository
    cost_rate: float          # cost units per second (paper: GPU >= 6x CPU)
    startup_latency: float    # seconds to provision a fresh worker


HARDWARE: Dict[str, HardwareSpec] = {
    # NOTE: cpu-host describes ONE replica slot (2 of 8 vCPUs), so CPU
    # replication scales throughput linearly (paper Fig. 4); a host offers
    # cores/cores_per_replica = 4 such slots and mem_capacity is host-wide.
    "cpu-host": HardwareSpec(
        name="cpu-host", kind="cpu", chips=0,
        peak_flops=0.15e12, mem_bw=20e9, mem_capacity=32 * 2**30,
        load_bw=1.5e9, cost_rate=1.0, startup_latency=8.0),
    "tpu-v5e-1": HardwareSpec(
        name="tpu-v5e-1", kind="accel", chips=1,
        peak_flops=V5E_PEAK_FLOPS_BF16, mem_bw=V5E_HBM_BW,
        mem_capacity=V5E_HBM_BYTES, load_bw=PCIE_LOAD_BW,
        cost_rate=6.0, startup_latency=15.0),
    "tpu-v5e-4": HardwareSpec(
        name="tpu-v5e-4", kind="accel", chips=4,
        peak_flops=4 * V5E_PEAK_FLOPS_BF16, mem_bw=4 * V5E_HBM_BW,
        mem_capacity=4 * V5E_HBM_BYTES, load_bw=4 * PCIE_LOAD_BW,
        cost_rate=24.0, startup_latency=20.0),
}


def roofline_latency(flops: float, bytes_moved: float,
                     hw: HardwareSpec, efficiency: float = 0.6) -> float:
    """max(compute, memory) time in seconds at a de-rated efficiency."""
    t_compute = flops / (hw.peak_flops * efficiency)
    t_memory = bytes_moved / (hw.mem_bw * efficiency)
    return max(t_compute, t_memory)
