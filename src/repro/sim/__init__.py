from repro.sim.clock import Clock, EventLoop, RealClock  # noqa: F401
from repro.sim.hardware import HARDWARE, HardwareSpec    # noqa: F401
