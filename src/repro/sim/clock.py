"""Clock abstraction: the control plane is written against ``Clock`` so the
same code runs under a discrete-event virtual clock (cluster-scale
experiments) or wall time (real execution on host)."""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, List, Optional, Tuple


class Clock:
    def now(self) -> float:
        raise NotImplementedError


class RealClock(Clock):
    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0


class EventLoop(Clock):
    """Deterministic discrete-event virtual clock.

    ``schedule(delay, fn)`` / ``schedule_at(t, fn)``; ``run_until(t)`` fires
    events in time order (FIFO for ties). Periodic tasks re-schedule
    themselves.
    """

    def __init__(self):
        self._t = 0.0
        self._heap: List[Tuple[float, int, Callable]] = []
        self._counter = itertools.count()

    def now(self) -> float:
        return self._t

    def schedule_at(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._heap, (max(t, self._t), next(self._counter), fn))

    def schedule(self, delay: float, fn: Callable) -> None:
        self.schedule_at(self._t + delay, fn)

    def every(self, period: float, fn: Callable, jitter: float = 0.0,
              stop: Optional[Callable[[], bool]] = None) -> None:
        def tick():
            if stop is not None and stop():
                return
            fn()
            self.schedule(period, tick)
        self.schedule(period + jitter, tick)

    def next_event_time(self) -> Optional[float]:
        """Time of the earliest scheduled event, or None when drained
        (lets callers — e.g. ``QueryHandle.result`` — pump event-by-event
        without overshooting a deadline)."""
        return self._heap[0][0] if self._heap else None

    def step(self) -> bool:
        """Fire exactly the next scheduled event; False when drained."""
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self._t = t
        fn()
        return True

    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._heap)
            self._t = t
            fn()
        self._t = max(self._t, t_end)

    def run_all(self, limit: int = 10_000_000) -> None:
        n = 0
        while self._heap and n < limit:
            t, _, fn = heapq.heappop(self._heap)
            self._t = t
            fn()
            n += 1
