"""Clock abstraction: the control plane is written against ``Clock`` so the
same code runs under a discrete-event virtual clock (cluster-scale
experiments) or wall time (real execution on host).

Both clocks implement the full scheduling surface (``schedule`` /
``schedule_at`` / ``every`` / ``next_event_time``): ``EventLoop`` fires
callbacks when a driver pumps ``step``/``run_until``, while ``RealClock``
fires them from a single daemon scheduler thread when wall time reaches the
deadline. Control-plane code that only ever runs from clock callbacks is
therefore single-threaded under either clock; the ``virtual`` attribute
tells blocking callers (``QueryHandle.result``) whether to pump the loop or
wait on a condition variable.
"""
from __future__ import annotations

import heapq
import itertools
import sys
import threading
import time
import traceback
from typing import Callable, List, Optional, Tuple


class Clock:
    #: True when time only advances by pumping the loop (EventLoop); False
    #: when callbacks fire asynchronously as wall time passes (RealClock).
    virtual: bool = True

    def now(self) -> float:
        raise NotImplementedError

    def schedule_at(self, t: float, fn: Callable) -> None:
        raise NotImplementedError

    def schedule(self, delay: float, fn: Callable) -> None:
        self.schedule_at(self.now() + delay, fn)

    def every(self, period: float, fn: Callable, jitter: float = 0.0,
              stop: Optional[Callable[[], bool]] = None) -> None:
        """Fire ``fn`` every ``period + jitter`` seconds until ``stop()``.

        ``jitter`` applies to *every* interval (a fixed per-task phase
        offset), so two tasks with the same period but different jitter
        never collapse onto the same firing times.
        """
        def tick():
            if stop is not None and stop():
                return
            fn()
            self.schedule(period + jitter, tick)
        self.schedule(period + jitter, tick)

    def next_event_time(self) -> Optional[float]:
        raise NotImplementedError

    def shutdown(self, timeout: float = 5.0) -> None:
        """Stop firing events. No-op for the virtual clock (nothing runs
        between pumps); ``RealClock`` overrides to join its scheduler
        thread, so teardown code can call this on either clock."""


class RealClock(Clock):
    """Wall clock with a condition-variable timer thread.

    ``schedule``/``every`` callbacks fire on one daemon scheduler thread
    (started lazily on first use), in deadline order, with the internal
    lock *released* during each callback — callbacks may freely schedule
    more work. A callback that raises is reported to stderr and does not
    kill the scheduler.
    """

    virtual = False

    def __init__(self):
        self._t0 = time.monotonic()
        self._cv = threading.Condition()
        self._heap: List[Tuple[float, int, Callable]] = []
        self._counter = itertools.count()
        self._thread: Optional[threading.Thread] = None
        self._stopped = False

    def now(self) -> float:
        return time.monotonic() - self._t0

    def schedule_at(self, t: float, fn: Callable) -> None:
        with self._cv:
            if self._stopped:
                return
            heapq.heappush(self._heap,
                           (max(t, self.now()), next(self._counter), fn))
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._run, name="realclock-scheduler", daemon=True)
                self._thread.start()
            self._cv.notify_all()

    def next_event_time(self) -> Optional[float]:
        with self._cv:
            return self._heap[0][0] if self._heap else None

    def pending(self) -> int:
        with self._cv:
            return len(self._heap)

    def run_until(self, t_end: float) -> None:
        """Block the calling thread until wall time ``t_end``; scheduled
        callbacks keep firing on the scheduler thread meanwhile."""
        while True:
            remaining = t_end - self.now()
            if remaining <= 0.0:
                return
            time.sleep(min(remaining, 0.05))

    def shutdown(self, timeout: float = 5.0) -> None:
        """Stop firing events and join the scheduler thread. Events still
        in the heap are dropped; subsequent ``schedule`` calls are no-ops."""
        with self._cv:
            self._stopped = True
            self._heap.clear()
            self._cv.notify_all()
            th = self._thread
        if th is not None and th.is_alive() \
                and th is not threading.current_thread():
            th.join(timeout)

    def _run(self) -> None:
        while True:
            fn = None
            with self._cv:
                if self._stopped:
                    return
                if not self._heap:
                    self._cv.wait()
                    continue
                delay = self._heap[0][0] - self.now()
                if delay > 0.0:
                    self._cv.wait(timeout=delay)
                    continue
                _, _, fn = heapq.heappop(self._heap)
            try:
                fn()
            except Exception:  # noqa: BLE001 - scheduler must survive
                print("RealClock callback raised:", file=sys.stderr)
                traceback.print_exc()


class EventLoop(Clock):
    """Deterministic discrete-event virtual clock.

    ``schedule(delay, fn)`` / ``schedule_at(t, fn)``; ``run_until(t)`` fires
    events in time order (FIFO for ties). Periodic tasks re-schedule
    themselves.
    """

    virtual = True

    def __init__(self):
        self._t = 0.0
        self._heap: List[Tuple[float, int, Callable]] = []
        self._counter = itertools.count()

    def now(self) -> float:
        return self._t

    def schedule_at(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._heap, (max(t, self._t), next(self._counter), fn))

    def next_event_time(self) -> Optional[float]:
        """Time of the earliest scheduled event, or None when drained
        (lets callers — e.g. ``QueryHandle.result`` — pump event-by-event
        without overshooting a deadline)."""
        return self._heap[0][0] if self._heap else None

    def step(self) -> bool:
        """Fire exactly the next scheduled event; False when drained."""
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self._t = t
        fn()
        return True

    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._heap)
            self._t = t
            fn()
        self._t = max(self._t, t_end)

    def run_all(self, limit: int = 10_000_000) -> None:
        n = 0
        while self._heap and n < limit:
            t, _, fn = heapq.heappop(self._heap)
            self._t = t
            fn()
            n += 1
