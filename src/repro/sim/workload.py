"""Workload generation (paper §8.5): Poisson arrivals, Zipf model popularity,
time-varying load levels."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.sim.clock import EventLoop


def poisson_arrivals(loop: EventLoop, rate_fn: Callable[[float], float],
                     fire: Callable[[float], None], t_end: float,
                     seed: int = 0, rate_cap: float = 1e4) -> None:
    """Schedule a non-homogeneous Poisson process by thinning.

    ``rate_fn(t)`` in events/s; ``fire(t)`` called per arrival.
    """
    rng = np.random.default_rng(seed)
    lam_max = max(rate_cap * 1e-9 + max(
        rate_fn(t) for t in np.linspace(0, t_end, 257)), 1e-9)

    t = 0.0
    while t < t_end:
        t += rng.exponential(1.0 / lam_max)
        if t >= t_end:
            break
        if rng.random() < rate_fn(t) / lam_max:
            tt = t
            loop.schedule_at(tt, (lambda ts: lambda: fire(ts))(tt))


def zipf_weights(n: int, alpha: float = 1.0) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** alpha
    return w / w.sum()


@dataclasses.dataclass
class PopularitySplit:
    """Paper §8.5: 20% of models are popular and share 80% of the load."""
    popular: List[str]
    cold: List[str]
    weights: Dict[str, float]


def popularity_split(archs: Sequence[str], seed: int = 0,
                     popular_frac: float = 0.2,
                     popular_load: float = 0.8) -> PopularitySplit:
    archs = list(archs)
    n_pop = max(1, int(round(popular_frac * len(archs))))
    popular, cold = archs[:n_pop], archs[n_pop:]
    weights: Dict[str, float] = {}
    pw = zipf_weights(len(popular)) * popular_load
    for a, w in zip(popular, pw):
        weights[a] = float(w)
    if cold:
        cw = (1.0 - popular_load) / len(cold)
        for a in cold:
            weights[a] = cw
    else:
        for a in popular:
            weights[a] /= popular_load
    return PopularitySplit(popular, cold, weights)


def step_rate(levels: Sequence[Tuple[float, float]]) -> Callable[[float], float]:
    """levels: [(duration_s, rate), ...] -> piecewise-constant rate_fn."""
    bounds = []
    t = 0.0
    for dur, rate in levels:
        t += dur
        bounds.append((t, rate))

    def rate_fn(tt: float) -> float:
        for end, rate in bounds:
            if tt < end:
                return rate
        return bounds[-1][1] if bounds else 0.0
    return rate_fn


def ramp_rate(t_end: float, start: float, peak: float,
              symmetric: bool = True) -> Callable[[float], float]:
    """Linear ramp start->peak (->start if symmetric) over t_end seconds."""
    def rate_fn(t: float) -> float:
        if not symmetric:
            return start + (peak - start) * min(t / t_end, 1.0)
        half = t_end / 2
        if t <= half:
            return start + (peak - start) * (t / half)
        return peak - (peak - start) * ((t - half) / half)
    return rate_fn
