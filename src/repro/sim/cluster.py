"""Cluster assembly helpers: wire up loop + metadata store + repository +
master + workers and register the assigned architecture zoo."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.api import INFaaS
from repro.core.master import Master, MasterConfig
from repro.core.metadata import MetadataStore
from repro.core.repository import ModelRepository
from repro.sim.clock import EventLoop


def serving_archs() -> List[ArchConfig]:
    """Archs with at least one variant on standard worker hardware
    (cpu-host / tpu-v5e-1); the giants that only fit multi-chip slices are
    exercised through the multi-pod dry-run instead."""
    from repro.configs.registry import ARCHS
    from repro.core import profiler as prof
    out = []
    for cfg in ARCHS.values():
        vs = prof.generate_variants(cfg)
        if any(v.hardware in ("cpu-host", "tpu-v5e-1") for v in vs):
            out.append(cfg)
    return out


@dataclasses.dataclass
class Cluster:
    loop: EventLoop
    store: MetadataStore
    repo: ModelRepository
    master: Master
    api: INFaaS

    def run_until(self, t: float) -> None:
        self.loop.run_until(t)


def make_cluster(n_accel: int = 1, n_cpu: int = 0,
                 archs: Optional[Sequence[ArchConfig]] = None,
                 autoscale: bool = True,
                 cfg: Optional[MasterConfig] = None,
                 backend: str = "sim",
                 engine_cfg=None) -> Cluster:
    """Assemble a cluster.

    ``backend="sim"`` (default): workers answer from profiled t(b) models —
    any scale, no JAX execution.

    ``backend="real"``: every worker gets an
    ``repro.serving.executor.EngineExecutor`` — jobs run for real on
    reduced-config continuous-batching engines (host CPU), measured service
    times drive the virtual clock, and variant profiles are re-fit from
    the measurements as they accumulate. Pass a small ``archs`` list (each
    arch builds real model params) and optionally an
    ``EngineExecutorConfig`` as ``engine_cfg``.
    """
    if backend not in ("sim", "real"):
        raise ValueError(f"unknown backend {backend!r} (sim|real)")
    loop = EventLoop()
    store = MetadataStore()
    repo = ModelRepository()
    use_archs = list(archs if archs is not None else serving_archs())
    executor_factory = None
    if backend == "real":
        from repro.serving.executor import (EngineExecutor,
                                            EngineExecutorConfig)
        arch_cfgs = {a.name: a.reduced() for a in use_archs}
        ecfg = engine_cfg or EngineExecutorConfig()
        model_cache: dict = {}   # share built params across workers

        def executor_factory():
            return EngineExecutor(arch_cfgs, ecfg, model_cache=model_cache)
    master = Master(store, repo, loop, cfg or MasterConfig(),
                    autoscale=autoscale, executor_factory=executor_factory)
    api = INFaaS(master)
    for cfgA in use_archs:
        master.register_model(cfgA)
    for _ in range(n_accel):
        master.add_worker("accel")
    for _ in range(n_cpu):
        master.add_worker("cpu")
    return Cluster(loop, store, repo, master, api)
