"""Cluster assembly helpers: wire up loop + metadata store + repository +
master + workers and register the assigned architecture zoo."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.api import INFaaS
from repro.core.master import Master, MasterConfig
from repro.core.metadata import MetadataStore
from repro.core.repository import ModelRepository
from repro.sim.clock import Clock, EventLoop, RealClock


def serving_archs() -> List[ArchConfig]:
    """Archs with at least one variant on standard worker hardware
    (cpu-host / tpu-v5e-1); the giants that only fit multi-chip slices are
    exercised through the multi-pod dry-run instead."""
    from repro.configs.registry import ARCHS
    from repro.core import profiler as prof
    out = []
    for cfg in ARCHS.values():
        vs = prof.generate_variants(cfg)
        if any(v.hardware in ("cpu-host", "tpu-v5e-1") for v in vs):
            out.append(cfg)
    return out


@dataclasses.dataclass
class Cluster:
    loop: Clock
    store: MetadataStore
    repo: ModelRepository
    master: Master
    api: INFaaS
    # real-backend executors created so far (one per worker): the wall-
    # clock runtime walks these to stop stepper threads at shutdown
    executors: List = dataclasses.field(default_factory=list)

    def run_until(self, t: float) -> None:
        self.loop.run_until(t)


def make_cluster(n_accel: int = 1, n_cpu: int = 0,
                 archs: Optional[Sequence[ArchConfig]] = None,
                 autoscale: bool = True,
                 cfg: Optional[MasterConfig] = None,
                 backend: str = "sim",
                 engine_cfg=None,
                 clock: str = "virtual") -> Cluster:
    """Assemble a cluster.

    ``backend="sim"`` (default): workers answer from profiled t(b) models —
    any scale, no JAX execution.

    ``backend="real"``: every worker gets an
    ``repro.serving.executor.EngineExecutor`` — jobs run for real on
    reduced-config continuous-batching engines (host CPU), measured service
    times drive the virtual clock, and variant profiles are re-fit from
    the measurements as they accumulate. Pass a small ``archs`` list (each
    arch builds real model params) and optionally an
    ``EngineExecutorConfig`` as ``engine_cfg``.

    ``clock="wall"`` (requires ``backend="real"``): the control plane runs
    against ``RealClock`` — callbacks fire on a scheduler thread as wall
    time passes — and every worker gets a ``ThreadedEngineExecutor``
    stepped by its own background thread, with token streaming enabled.
    Wrap the result in ``repro.serving.runtime.ServingRuntime`` for
    thread-safe submission and drain-on-shutdown.
    """
    if backend not in ("sim", "real"):
        raise ValueError(f"unknown backend {backend!r} (sim|real)")
    if clock not in ("virtual", "wall"):
        raise ValueError(f"unknown clock {clock!r} (virtual|wall)")
    if clock == "wall" and backend != "real":
        raise ValueError("clock='wall' requires backend='real': the sim "
                         "executor has no work to do in real time")
    loop: Clock = RealClock() if clock == "wall" else EventLoop()
    store = MetadataStore()
    repo = ModelRepository()
    use_archs = list(archs if archs is not None else serving_archs())
    executor_factory = None
    executors: List = []
    if backend == "real":
        from repro.serving.executor import (EngineExecutor,
                                            EngineExecutorConfig)
        arch_cfgs = {a.name: a.reduced() for a in use_archs}
        ecfg = engine_cfg or EngineExecutorConfig()
        model_cache: dict = {}   # share built params across workers

        if clock == "wall":
            from repro.serving.runtime import ThreadedEngineExecutor
            ecfg = dataclasses.replace(ecfg, stream=True)

            def executor_factory():
                ex = ThreadedEngineExecutor(arch_cfgs, ecfg,
                                            model_cache=model_cache)
                executors.append(ex)
                return ex
        else:
            def executor_factory():
                ex = EngineExecutor(arch_cfgs, ecfg,
                                    model_cache=model_cache)
                executors.append(ex)
                return ex
    master = Master(store, repo, loop, cfg or MasterConfig(),
                    autoscale=autoscale, executor_factory=executor_factory)
    api = INFaaS(master)
    for cfgA in use_archs:
        master.register_model(cfgA)
    for _ in range(n_accel):
        master.add_worker("accel")
    for _ in range(n_cpu):
        master.add_worker("cpu")
    return Cluster(loop, store, repo, master, api, executors=executors)
