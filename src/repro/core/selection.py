"""Model-variant selection (paper §5, Algorithm 1) with the decision cache.

Three outcomes, in order:
  1. decision cache hit and the cached variant is running & not overloaded;
  2. scan of the architecture's variants for a running, valid, non-overloaded
     one (use-case queries scan the top-N=7 accuracy-qualified variants);
  3. pick the variant minimizing (load latency + inference latency) and load
     it on the least-utilized worker with the target hardware.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.abstraction import Variant
from repro.core.metadata import InstanceState, MetadataStore
from repro.sim import hardware as HW


@dataclasses.dataclass
class Selection:
    variant: Optional[Variant]
    worker: Optional[str]
    needs_load: bool
    outcome: str          # "cache" | "running" | "load" | "reject"
    reason: str = ""


def _is_valid(v: Variant, batch: int, latency_slo: Optional[float]) -> bool:
    if batch > v.profile.max_batch:
        return False
    if latency_slo is not None and v.profile.latency(batch) > latency_slo:
        return False
    return True


class VariantSelector:
    def __init__(self, store: MetadataStore, top_n: int = 7):
        self.store = store
        self.top_n = top_n
        self._cache = {}   # key -> variant name

    # ------------------------------------------------------------------
    def _least_loaded_worker(self, insts: List[InstanceState]) -> InstanceState:
        return min(insts, key=lambda i: i.qps)

    def _pick_running(self, cands: List[Variant], batch: int,
                      slo: Optional[float]) -> Optional[Selection]:
        for v in cands:
            if not _is_valid(v, batch, slo):
                continue
            insts = [i for i in self.store.running_instances_of(v.name)
                     if not self.store.is_overloaded(i)]
            if insts:
                inst = self._least_loaded_worker(insts)
                return Selection(v, inst.worker, False, "running")
        return None

    def _pick_load(self, cands: List[Variant], batch: int,
                   slo: Optional[float]) -> Selection:
        """Outcome 3: lowest combined loading+inference latency."""
        best: Optional[Tuple[float, Variant, str]] = None
        for v in cands:
            if batch > v.profile.max_batch:
                continue
            total = v.profile.load_latency + v.profile.latency(batch)
            if slo is not None and v.profile.latency(batch) > slo:
                # keep as fallback only: inference alone violates -> skip
                continue
            worker = self._worker_for_load(v)
            if worker is None:
                continue
            if best is None or total < best[0]:
                best = (total, v, worker)
        if best is None:
            # relax: allow any variant that fits the batch (paper falls back
            # to the lowest-latency option rather than rejecting outright)
            for v in sorted(cands, key=lambda x: x.profile.load_latency
                            + x.profile.latency(min(batch, x.profile.max_batch))):
                if batch > v.profile.max_batch:
                    continue
                worker = self._worker_for_load(v)
                if worker is not None:
                    return Selection(v, worker, True, "load",
                                     reason="slo-relaxed")
            return Selection(None, None, False, "reject",
                             reason="no feasible variant/worker")
        return Selection(best[1], best[2], True, "load")

    def _worker_for_load(self, v: Variant) -> Optional[str]:
        """Least-utilized live worker with the hardware + free memory."""
        best = None
        for w in self.store.workers.values():
            if not w.alive or w.blacklisted or v.hardware not in w.hardware:
                continue
            cap = HW.HARDWARE[v.hardware].mem_capacity
            used = w.mem_used.get(v.hardware, 0.0)
            if used + v.profile.peak_memory > cap:
                continue
            util = w.util.get(v.hardware, 0.0)
            if best is None or util < best[0]:
                best = (util, w.name)
        return best[1] if best else None

    # ------------------------------------------------------------------
    def select_arch(self, arch: str, batch: int,
                    latency_slo: Optional[float]) -> Selection:
        key = ("arch", arch, batch, None if latency_slo is None
               else round(latency_slo, 4))
        sel = self._try_cache(key, batch, latency_slo)
        if sel is not None:
            return sel
        cands = sorted(self.store.registry.variants_of(arch),
                       key=lambda v: v.profile.latency(batch)
                       if batch <= v.profile.max_batch else float("inf"))
        sel = self._pick_running(cands, batch, latency_slo) \
            or self._pick_load(cands, batch, latency_slo)
        self._remember(key, sel)
        return sel

    def select_usecase(self, task: str, dataset: str, accuracy: float,
                       batch: int, latency_slo: Optional[float],
                       user: str = "public") -> Selection:
        key = ("usecase", task, dataset, round(accuracy, 4), batch,
               None if latency_slo is None else round(latency_slo, 4))
        sel = self._try_cache(key, batch, latency_slo)
        if sel is not None:
            return sel
        cands = self.store.registry.top_variants_for_usecase(
            task, dataset, accuracy, n=self.top_n, user=user)
        if not cands:
            return Selection(None, None, False, "reject",
                             reason="no variant meets accuracy")
        sel = self._pick_running(cands, batch, latency_slo) \
            or self._pick_load(cands, batch, latency_slo)
        self._remember(key, sel)
        return sel

    def select_variant(self, variant: str, batch: int) -> Selection:
        """User named the variant explicitly: only pick the worker."""
        v = self.store.variant(variant)
        insts = [i for i in self.store.running_instances_of(v.name)
                 if not self.store.is_overloaded(i)]
        if insts:
            inst = self._least_loaded_worker(insts)
            return Selection(v, inst.worker, False, "running")
        worker = self._worker_for_load(v)
        if worker is None:
            return Selection(None, None, False, "reject", reason="no worker")
        return Selection(v, worker, True, "load")

    # ------------------------------------------------------------------
    def _try_cache(self, key, batch, slo) -> Optional[Selection]:
        name = self._cache.get(key)
        if name is None:
            return None
        v = self.store.registry.variants.get(name)
        if v is None or not _is_valid(v, batch, slo):
            self._cache.pop(key, None)
            return None
        insts = [i for i in self.store.running_instances_of(v.name)
                 if not self.store.is_overloaded(i)]
        if not insts:
            self._cache.pop(key, None)   # stale: fall through to full scan
            return None
        inst = self._least_loaded_worker(insts)
        return Selection(v, inst.worker, False, "cache")

    def _remember(self, key, sel: Selection) -> None:
        if sel.variant is not None and sel.outcome in ("running", "load"):
            self._cache[key] = sel.variant.name

    def invalidate(self, variant: str) -> None:
        for k in [k for k, v in self._cache.items() if v == variant]:
            self._cache.pop(k, None)
