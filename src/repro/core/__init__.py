"""INFaaS core: the paper's contribution (model-less abstraction, variant
selection, two-level autoscaling, multi-tenant sharing)."""
from repro.core.api import INFaaS                      # noqa: F401
from repro.core.master import Master, MasterConfig     # noqa: F401
from repro.core.metadata import MetadataStore          # noqa: F401
from repro.core.repository import ModelRepository      # noqa: F401
from repro.core.selection import VariantSelector       # noqa: F401
from repro.core.worker import Query, Worker, WorkerConfig  # noqa: F401
