"""Worker: executors, per-variant queues with adaptive batching, monitoring
daemon, and offline best-effort execution (paper §4, §6.2, §8.3).

Execution model (DESIGN.md §2): an accelerator device is a single temporal-
sharing resource (one job in service, FIFO across co-resident variants; no
replication on-accelerator, per paper §6.2); the host CPU offers
``cores // cores_per_replica`` concurrent slots and variants scale on it by
replication.

The data plane behind a device is pluggable through the ``Executor``
protocol: ``run(variant, batch, requests)`` returns the service time of
one batch; ``requests`` carries each co-batched query's ``ExecRequest``
(real payload prompts in, generated token ids out via ``on_outputs``).
``SimExecutor`` (default) answers from the variant's profiled
t(b) = m*b + c; ``repro.serving.executor.EngineExecutor`` actually runs the
batch through a real continuous-batching ``ServingEngine`` and returns the
measured wall time. Everything downstream — ``_submit``/``_complete``, the
monitoring daemon, and model-level autoscaling — operates identically over
both, so the INFaaS control plane drives simulated and real execution
through the same seam. (``EngineExecutor`` lives in ``repro.serving`` so
the control plane stays importable without JAX.)
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Protocol,
                    Tuple, runtime_checkable)

from repro.core.metadata import InstanceState, MetadataStore
from repro.core.repository import ModelRepository
from repro.sim import hardware as HW
from repro.sim.clock import Clock


def _locked(fn):
    """Serialize a Worker method under the instance lock. Under the
    EventLoop every entry point already runs on the single pumping thread;
    under the wall-clock runtime, clock callbacks (scheduler thread) and
    executor completions (stepper threads) interleave, so every method that
    mutates pending/in-flight maps takes the reentrant lock."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._lock:
            return fn(self, *args, **kwargs)
    return wrapper


@dataclasses.dataclass
class Query:
    qid: int
    kind: str                       # "online" | "offline"
    n_inputs: int
    slo: Optional[float]
    arrival: float
    arch: str = ""
    variant: str = ""
    # use-case granularity (paper §3.2): kept as flat fields for metrics
    # attribution; the authoritative description is ``spec``
    task: str = ""
    dataset: str = ""
    min_accuracy: float = 0.0
    user: str = "public"
    # the immutable api.QuerySpec this query was built from; redispatch
    # and hedging replay it instead of re-deriving granularity from the
    # sentinel fields above (typed Any: the control plane stays free of an
    # api-module import cycle)
    spec: Any = None
    # api.QueryPayload: real token-id prompts threaded down to the
    # executor; ``outputs`` comes back from a real engine (one token-id
    # array per prompt, submission order)
    payload: Any = None
    outputs: Optional[List[Any]] = None
    load_wait: float = 0.0          # load latency this query paid
    worker: str = ""
    start: float = -1.0
    finish: float = -1.0
    violated: bool = False
    failed: bool = False
    cancelled: bool = False         # hedging: the losing copy is cancelled
    hedge_of: Optional[int] = None
    # dispatch attempts so far (1 = first try); the master stamps this on
    # every (re)dispatch so results can surface how hard placement was
    attempts: int = 0
    # served correctly but on borrowed time: some of this query's work was
    # preempted under memory pressure and recovered (bit-identical replay)
    degraded: bool = False
    preemptions: int = 0            # engine preempt count behind `degraded`
    done_cb: Optional[Callable[["Query"], None]] = None
    # streaming sink: called (input_idx, new_tokens, t_wall) as decode
    # segments retire on a streaming executor; None = no streaming
    on_tokens: Optional[Callable[[int, List[int], float], None]] = None
    # wall time of the query's first streamed tokens (-1 until then);
    # first_token - arrival is the query's TTFT
    first_token: float = -1.0

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


@dataclasses.dataclass
class OfflineJob:
    jid: int
    variant: str
    total_inputs: int
    processed: int = 0
    spec: Any = None                # api.QuerySpec (mode="offline")
    payload: Any = None             # api.QueryPayload; chunks are sliced
    #                                 from it as the job advances
    outputs: List[Any] = dataclasses.field(default_factory=list)
    arrival: float = 0.0
    finish: float = -1.0
    failed: bool = False            # no capacity after max_retries
    attempts: int = 0               # placement attempts (backoff between)
    degraded: bool = False          # any chunk recovered from a preempt
    done_cb: Optional[Callable[["OfflineJob"], None]] = None

    @property
    def done(self) -> bool:
        return self.processed >= self.total_inputs


@dataclasses.dataclass
class ExecRequest:
    """One logical query's slice of a device batch, handed to the Executor.

    ``prompts`` carries the query's real token-id prompts (empty tuple ->
    the executor substitutes synthetic inputs, ``n_inputs`` of them).
    ``on_outputs`` is called with the per-input generated token-id arrays
    when a real executor finishes the batch; sim executors ignore it.
    ``slo`` threads the query's latency objective down to the engine's
    SLO-aware preemption; ``on_report`` carries the degradation verdict
    (preemption counts) back when a real executor finishes.
    """
    n_inputs: int
    prompts: Tuple = ()
    max_new_tokens: int = 0         # 0 -> executor default
    on_outputs: Optional[Callable[[List[Any]], None]] = None
    slo: Optional[float] = None
    on_report: Optional[Callable[[Dict[str, Any]], None]] = None
    # streaming sink: (input_idx, new_tokens, t_wall) per harvested
    # segment, in emission order; only streaming executors call it
    on_tokens: Optional[Callable[[int, List[int], float], None]] = None


@runtime_checkable
class Executor(Protocol):
    """Data plane behind a worker device.

    ``run(variant, batch, requests)`` performs (or models) the service of
    one batch on the variant and returns its service time in seconds.
    ``requests`` (optional) carries one ``ExecRequest`` per co-batched
    query — real payload prompts in, generated tokens out via each
    request's ``on_outputs`` sink. Called when a job actually starts on a
    device slot; the worker schedules the job's completion that far into
    the future, so simulated and real execution share the whole
    dispatch/monitor/autoscale machinery.
    """

    def run(self, variant, batch: int,
            requests: Optional[List[ExecRequest]] = None) -> float:
        ...

    # Executors may additionally expose
    #   run_async(variant, batch, requests, on_done)
    # returning immediately; ``on_done(duration, error)`` fires later from
    # the executor's own thread. When present, the worker routes jobs
    # through it instead of blocking the clock thread in ``run`` — see
    # ``repro.serving.runtime.ThreadedEngineExecutor``.


class SimExecutor:
    """Profile-driven executor: service time from the variant's t(b) fit
    (optionally overridden by a ``service_time_fn(variant, batch)``).
    Payloads are accounted but not executed — no outputs are produced."""

    def __init__(self, service_time_fn: Optional[Callable] = None):
        self.service_time_fn = service_time_fn

    def run(self, variant, batch: int,
            requests: Optional[List[ExecRequest]] = None) -> float:
        if self.service_time_fn is not None:
            return self.service_time_fn(variant, batch)
        return variant.profile.latency(batch)


@dataclasses.dataclass
class WorkerConfig:
    monitor_period: float = 2.0
    autoscale_period: float = 1.0
    headroom: float = 0.05          # absorb 5% spikes (paper §6.2)
    t_down_cpu: int = 10            # scale-down hysteresis (paper §6.2)
    t_down_accel: int = 20
    cpu_cores: int = 8
    cores_per_replica: int = 2
    qps_window: float = 4.0         # EWMA window for rate estimates
    offline_util_cap: float = 0.9   # pause offline above this CPU util


class _Device:
    def __init__(self, hw: HW.HardwareSpec, slots: int):
        self.hw = hw
        self.slots = slots
        self.active = 0
        self.mem_used = 0.0
        self.busy_accum = 0.0       # busy seconds since last monitor tick
        self.window_start = 0.0     # time of the last monitor tick
        self.running: set = set()   # in-flight _Jobs (for live busy credit)
        self.waiting: Deque = deque()

    @property
    def idle(self) -> bool:
        return self.active == 0 and not self.waiting


class _Job:
    __slots__ = ("instance", "queries", "batch", "offline_job", "duration",
                 "start_time", "requests", "abandoned")

    def __init__(self, instance, queries, batch, offline_job=None,
                 requests=None):
        self.instance = instance
        self.queries = queries
        self.batch = batch
        self.offline_job = offline_job
        self.duration = 0.0
        self.start_time = 0.0
        # per-query ExecRequests: real payload prompts down, outputs back
        self.requests: List[ExecRequest] = requests or []
        # worker failed over while this job was queued/in flight: its
        # queries were already failed through the retry path, so the
        # stale scheduled completion must become a no-op
        self.abandoned = False


class _LocalInstance:
    """Worker-local execution state of one variant instance."""

    def __init__(self, variant, replicas: int = 1):
        self.variant = variant      # abstraction.Variant
        self.replicas = replicas
        self.outstanding = 0
        self.pending: Deque[Query] = deque()
        # stats since last monitor tick
        self.completed_inputs = 0.0
        self.lat_sum = 0.0
        self.lat_n = 0
        self.running = False


class Worker:
    def __init__(self, name: str, hardware, store: MetadataStore,
                 repo: ModelRepository, loop: Clock,
                 cfg: WorkerConfig = WorkerConfig(),
                 metrics: Optional[List[Query]] = None,
                 service_time_fn: Optional[Callable] = None,
                 slowdown: float = 1.0,
                 executor: Optional[Executor] = None):
        self.name = name
        self.hardware = tuple(hardware)
        self.store = store
        self.repo = repo
        self.loop = loop
        self.cfg = cfg
        # guards pending/in-flight maps against stepper-thread completions
        # under the wall-clock runtime (reentrant: _complete -> dispatch)
        self._lock = threading.RLock()
        self.metrics = metrics if metrics is not None else []
        self.alive = True
        # fault injection: a hung worker is alive but frozen — heartbeats
        # stop, in-flight jobs never complete, nothing new dispatches.
        # Only the master's heartbeat sweep can detect and fail it.
        self._hung = False
        self.slowdown = slowdown    # straggler injection (>1 = slow worker)
        self.instances: Dict[str, _LocalInstance] = {}
        self.offline_jobs: List[OfflineJob] = []
        self.recent_violations = 0
        self.executor: Executor = executor if executor is not None \
            else SimExecutor(service_time_fn)
        self.devices: Dict[str, _Device] = {}
        for hname in self.hardware:
            hw = HW.HARDWARE[hname]
            slots = 1 if hw.kind == "accel" else max(
                1, cfg.cpu_cores // cfg.cores_per_replica)
            self.devices[hname] = _Device(hw, slots)
        store.upsert_worker(name, self.hardware, loop.now())
        store.heartbeat(name, {h: 0.0 for h in self.hardware},
                        {h: 0.0 for h in self.hardware}, loop.now())
        loop.every(cfg.monitor_period, self.monitor_tick,
                   stop=lambda: not self.alive)

    # ------------------------------------------------------------------
    # variant lifecycle
    @_locked
    def load_variant(self, variant, on_ready: Optional[Callable] = None,
                     replicas: int = 1) -> bool:
        """Start loading a variant; becomes running after its load latency."""
        dev = self.devices.get(variant.hardware)
        if dev is None:
            return False   # this worker lacks the target hardware
        mem = variant.profile.peak_memory
        if dev.mem_used + mem > dev.hw.mem_capacity:
            return False
        if variant.name in self.instances:
            return True
        dev.mem_used += mem
        li = _LocalInstance(variant, replicas)
        self.instances[variant.name] = li
        inst = InstanceState(variant=variant.name, worker=self.name,
                            replicas=replicas, running=False, loading=True)
        self.store.set_instance(inst)

        def ready():
            with self._lock:
                if not self.alive or variant.name not in self.instances:
                    return
                li.running = True
                st = self.store.instance(variant.name, self.name)
                if st is not None:
                    st.loading = False
                    st.running = True
                self._try_dispatch(variant.name)
                self._pump_offline()
            if on_ready:
                on_ready()

        self.loop.schedule(variant.profile.load_latency * self.slowdown,
                           ready)
        return True

    @_locked
    def unload_variant(self, vname: str) -> None:
        li = self.instances.pop(vname, None)
        if li is None:
            return
        dev = self.devices[li.variant.hardware]
        dev.mem_used -= li.variant.profile.peak_memory
        self.store.drop_instance(vname, self.name)
        for q in li.pending:   # re-dispatch responsibility is the master's
            q.failed = True
            if q.done_cb:
                q.done_cb(q)

    @_locked
    def set_replicas(self, vname: str, replicas: int) -> None:
        li = self.instances.get(vname)
        if li is None:
            return
        li.replicas = max(1, replicas)
        st = self.store.instance(vname, self.name)
        if st is not None:
            st.replicas = li.replicas
        self._try_dispatch(vname)

    # ------------------------------------------------------------------
    # query path
    @_locked
    def enqueue(self, q: Query, vname: str) -> None:
        if not self.alive:
            q.failed = True
            if q.done_cb:
                q.done_cb(q)
            return
        li = self.instances.get(vname)
        if li is None:
            q.failed = True
            if q.done_cb:
                q.done_cb(q)
            return
        q.worker = self.name
        li.pending.append(q)
        if li.running:
            self._try_dispatch(vname)

    def _concurrency(self, li: _LocalInstance) -> int:
        hw = HW.HARDWARE[li.variant.hardware]
        return 1 if hw.kind == "accel" else li.replicas

    def _service_time(self, job: _Job) -> float:
        return self.executor.run(job.instance.variant, job.batch,
                                 job.requests or None) * self.slowdown

    def _exec_request(self, q: Query) -> ExecRequest:
        """The executor-facing slice of one query: real prompts when the
        query carries a payload (outputs land back on ``q.outputs``),
        synthetic accounting otherwise — tokens decoded from synthetic
        stand-ins are not answers, so no sink is attached. Either way the
        query's SLO rides along (the engine's preemption policy is
        slack-based) and any degradation report lands back on the query."""

        def report(rep, qq=q):
            qq.preemptions += int(rep.get("preemptions", 0))
            qq.degraded = qq.degraded or bool(rep.get("degraded"))

        def tokens(idx, toks, _t, qq=q):
            # re-stamp on the control plane's clock (the engine timestamps
            # on its own perf_counter base): first_token - arrival is then
            # the query's TTFT on the same timebase as every other metric.
            # A hedged/cancelled copy stops forwarding, but the TTFT
            # measurement stands.
            t = self.loop.now()
            if qq.first_token < 0.0:
                qq.first_token = t
            if qq.on_tokens is not None and not qq.cancelled:
                qq.on_tokens(idx, toks, t)

        if q.payload is not None:
            return ExecRequest(
                n_inputs=q.n_inputs, prompts=q.payload.prompts,
                max_new_tokens=q.payload.max_new_tokens,
                on_outputs=lambda outs, qq=q: setattr(qq, "outputs", outs),
                slo=q.slo, on_report=report,
                on_tokens=tokens if q.on_tokens is not None else None)
        return ExecRequest(n_inputs=q.n_inputs, slo=q.slo,
                           on_report=report)

    @_locked
    def _try_dispatch(self, vname: str) -> None:
        li = self.instances.get(vname)
        if li is None or not li.running or self._hung:
            return
        dev = self.devices[li.variant.hardware]
        while li.pending and li.outstanding < self._concurrency(li):
            # adaptive batching: drain up to the variant's max batch
            queries: List[Query] = []
            batch = 0
            while li.pending and batch < li.variant.profile.max_batch:
                nxt = li.pending[0]
                if nxt.cancelled:
                    li.pending.popleft()
                    continue
                if batch + nxt.n_inputs > li.variant.profile.max_batch \
                        and queries:
                    break
                q = li.pending.popleft()
                queries.append(q)
                batch += q.n_inputs
            if not queries:
                return
            job = _Job(li, queries, batch,
                       requests=[self._exec_request(q) for q in queries])
            li.outstanding += 1
            self._submit(dev, job)

    def _submit(self, dev: _Device, job: _Job) -> None:
        if dev.active < dev.slots:
            self._start(dev, job)
        else:
            dev.waiting.append(job)

    def _start(self, dev: _Device, job: _Job) -> None:
        run_async = getattr(self.executor, "run_async", None)
        if run_async is not None:
            self._start_async(dev, job, run_async)
            return
        # service time is resolved when the job actually starts on a slot:
        # a real executor runs the batch here (and measures it), a sim
        # executor just evaluates the profile — either way the completion
        # is scheduled that far into the future
        try:
            job.duration = self._service_time(job)
        except Exception:
            # a bad batch (e.g. a payload exceeding the real engine's
            # max_len) must not escape into the event loop and wedge the
            # device slot: fail the work, keep the slot usable
            self._fail_job(dev, job)
            return
        dev.active += 1
        now = self.loop.now()
        job.start_time = now
        dev.running.add(job)
        for q in job.queries:
            if q.start < 0:
                q.start = now
        self.loop.schedule(job.duration, lambda: self._complete(dev, job))

    def _start_async(self, dev: _Device, job: _Job,
                     run_async: Callable) -> None:
        """Wall-clock path: hand the job to a threaded executor and return
        immediately — the clock thread never blocks on real decode. The
        executor's stepper thread calls ``on_done`` when the batch retires;
        completion is marshaled back through ``loop.schedule(0, ...)`` so
        ``_complete`` runs on the scheduler thread like every other
        control-plane callback (the worker lock covers the overlap)."""
        dev.active += 1
        now = self.loop.now()
        job.start_time = now
        dev.running.add(job)
        for q in job.queries:
            if q.start < 0:
                q.start = now

        def on_done(duration: float, error=None):
            def finish():
                if error is not None:
                    with self._lock:
                        dev.active -= 1
                        dev.running.discard(job)
                        self._fail_job(dev, job)
                    return
                job.duration = duration
                self._complete(dev, job)
            self.loop.schedule(0.0, finish)

        try:
            run_async(job.instance.variant, job.batch,
                      job.requests or None, on_done)
        except Exception:
            dev.active -= 1
            dev.running.discard(job)
            self._fail_job(dev, job)

    @_locked
    def _fail_job(self, dev: _Device, job: _Job) -> None:
        """Executor rejected the batch before it started: surface failure
        (the master's retry path owns what happens next) and keep the
        device draining."""
        li = job.instance
        if job.offline_job is None:
            li.outstanding -= 1
            for q in job.queries:
                q.failed = True
                if q.done_cb:
                    q.done_cb(q)
        else:
            job.offline_job.failed = True
            if job.offline_job in self.offline_jobs:
                # drop it, or _pump_offline would retry the poisoned
                # chunk on every monitor tick forever
                self.offline_jobs.remove(job.offline_job)
            if job.offline_job.done_cb:
                job.offline_job.done_cb(job.offline_job)
        if dev.waiting and dev.active < dev.slots:
            self._start(dev, dev.waiting.popleft())

    @_locked
    def _complete(self, dev: _Device, job: _Job) -> None:
        if job.abandoned or self._hung:
            # abandoned: fail() already failed this job's queries through
            # the retry path — completing it too would double-fire their
            # callbacks onto the retried copies. Hung: a frozen worker
            # finishes nothing; the job stays wedged until the master's
            # heartbeat sweep fails this worker.
            return
        if not self.alive:
            # worker died mid-flight: surface the failure to the master
            for q in job.queries:
                q.failed = True
                if q.done_cb:
                    q.done_cb(q)
            return
        dev.active -= 1
        dev.running.discard(job)
        now = self.loop.now()
        # credit only the part of the job inside the current monitor window;
        # the earlier part was credited live by monitor_tick
        dev.busy_accum += now - max(job.start_time, dev.window_start)
        li = job.instance
        if job.offline_job is None:
            li.outstanding -= 1
            for q in job.queries:
                q.finish = now
                q.variant = li.variant.name
                if q.slo is not None and q.latency > q.slo:
                    q.violated = True
                    self.recent_violations += 1
                li.completed_inputs += q.n_inputs
                li.lat_sum += q.latency
                li.lat_n += 1
                self.metrics.append(q)
                if q.done_cb:
                    q.done_cb(q)
        else:
            job.offline_job.processed += job.batch
            li.completed_inputs += job.batch
            if job.offline_job.done and job.offline_job.done_cb:
                job.offline_job.done_cb(job.offline_job)
        # drain device queue, then instance queues, then offline slack
        if dev.waiting and dev.active < dev.slots:
            self._start(dev, dev.waiting.popleft())
        if self.alive:
            if job.offline_job is None:
                self._try_dispatch(li.variant.name)
            self._pump_offline()

    # ------------------------------------------------------------------
    # offline best-effort (paper §8.3, Fig. 10)
    @_locked
    def submit_offline(self, job: OfflineJob) -> None:
        self.offline_jobs.append(job)
        self._pump_offline()

    def _offline_throttled(self) -> bool:
        if self.recent_violations > 0:
            return True
        cpu = self.devices.get("cpu-host")
        if cpu is not None:
            # crude live-util probe: all slots busy -> back off
            if cpu.active >= cpu.slots:
                return True
        return False

    @_locked
    def _pump_offline(self) -> None:
        if not self.alive or self._hung or self._offline_throttled():
            return
        for job in list(self.offline_jobs):
            if job.done or job.failed:
                self.offline_jobs.remove(job)
                continue
            li = self.instances.get(job.variant)
            if li is None or not li.running:
                continue
            dev = self.devices[li.variant.hardware]
            # only absorb slack: device must be idle and no online backlog
            if not dev.idle or li.pending:
                continue
            chunk = min(job.total_inputs - job.processed,
                        li.variant.profile.max_batch)
            reqs = []
            if job.payload is not None:
                # slice this chunk's real prompts from the staged payload
                # (one chunk in flight per device: dev.idle gate above)
                sl = job.payload.prompts[job.processed:job.processed + chunk]
                reqs = [ExecRequest(
                    n_inputs=chunk, prompts=sl,
                    max_new_tokens=job.payload.max_new_tokens,
                    on_outputs=lambda outs, jj=job: jj.outputs.extend(outs),
                    on_report=lambda rep, jj=job: setattr(
                        jj, "degraded",
                        jj.degraded or bool(rep.get("degraded"))))]
            j = _Job(li, [], chunk, offline_job=job, requests=reqs)
            self._submit(dev, j)

    # ------------------------------------------------------------------
    # monitoring daemon (2 s updates, paper §4/§7)
    @_locked
    def monitor_tick(self) -> None:
        if not self.alive or self._hung:
            return
        now = self.loop.now()
        window = self.cfg.monitor_period
        util, mem = {}, {}
        for hname, dev in self.devices.items():
            # completed-in-window time plus the elapsed share of in-flight
            # jobs — otherwise long-running jobs report an idle device for
            # their whole service time and mislead the autoscaler
            busy = dev.busy_accum + sum(
                now - max(j.start_time, dev.window_start)
                for j in dev.running)
            util[hname] = min(1.0, busy / (window * dev.slots))
            mem[hname] = dev.mem_used
            dev.busy_accum = 0.0
            dev.window_start = now
        self.store.heartbeat(self.name, util, mem, now)
        for vname, li in self.instances.items():
            st = self.store.instance(vname, self.name)
            if st is None:
                continue
            qps = li.completed_inputs / window
            st.qps = 0.5 * st.qps + 0.5 * qps
            if li.lat_n:
                st.avg_latency = li.lat_sum / li.lat_n
            st.replicas = li.replicas
            st.running = li.running
            li.completed_inputs = 0.0
            li.lat_sum, li.lat_n = 0.0, 0
        self.recent_violations = 0
        self._pump_offline()   # periodic re-probe for slack

    # ------------------------------------------------------------------
    # failure injection (fault-tolerance tests)
    def hang(self) -> None:
        """Freeze the worker without marking it dead: heartbeats stop,
        in-flight jobs never complete, new work queues but never runs.
        Models a wedged machine — only the master's heartbeat sweep can
        detect it (``Master._failure_sweep`` then calls ``fail()``, which
        routes every stranded query into the retry path)."""
        self._hung = True

    @_locked
    def fail(self) -> None:
        """Kill the worker: everything it holds — pending queries, jobs
        waiting on a device, and jobs in flight — fails through ``done_cb``
        so the master's retry machinery re-dispatches it elsewhere. The
        jobs' already-scheduled completions are marked abandoned and
        become no-ops."""
        self.alive = False
        self.store.mark_dead(self.name)
        for dev in self.devices.values():
            for job in list(dev.running) + list(dev.waiting):
                self._abandon_job(job)
            dev.running.clear()
            dev.waiting.clear()
            dev.active = 0
        for li in self.instances.values():
            li.outstanding = 0
            for q in li.pending:
                q.failed = True
                if q.done_cb:
                    q.done_cb(q)
            li.pending.clear()

    def _abandon_job(self, job: _Job) -> None:
        """Fail a queued/in-flight job of a dead worker: queries go back
        to the master's retry path, offline jobs surface failure."""
        job.abandoned = True
        if job.offline_job is None:
            for q in job.queries:
                q.failed = True
                if q.done_cb:
                    q.done_cb(q)
        else:
            job.offline_job.failed = True
            if job.offline_job in self.offline_jobs:
                self.offline_jobs.remove(job.offline_job)
            if job.offline_job.done_cb:
                job.offline_job.done_cb(job.offline_job)
