"""Master: front-end, dispatcher & load balancer, hedged-request straggler
mitigation, and worker-lifecycle management (paper §4, Fig. 6).

The master is logically centralized; its durable state lives in the metadata
store (snapshot/restore covers master failure per paper §7). Decision latency
of every selection is recorded for the overhead analysis (paper §8.6).
"""
from __future__ import annotations

import dataclasses
import itertools
import random
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core import profiler as prof
from repro.core.abstraction import ModelArchInfo, Variant
from repro.core.api import (ArchTarget, QueryHandle, QuerySpec,
                            UseCaseTarget, VariantTarget, _spec_from_kwargs)
from repro.core.autoscaler import (MasterAutoscaler, MasterScaleConfig,
                                   WorkerAutoscaler)
from repro.core.metadata import MetadataStore
from repro.core.repository import ModelRepository
from repro.core.selection import Selection, VariantSelector
from repro.core.worker import OfflineJob, Query, Worker, WorkerConfig
from repro.sim import hardware as HW
from repro.sim.clock import Clock


@dataclasses.dataclass
class MasterConfig:
    worker: WorkerConfig = dataclasses.field(default_factory=WorkerConfig)
    scale: MasterScaleConfig = dataclasses.field(
        default_factory=MasterScaleConfig)
    hedge_enabled: bool = False
    hedge_factor: float = 3.0       # hedge when elapsed > factor * expected
    # bounded retry with exponential backoff + jitter: retry k (1-based)
    # waits min(retry_delay * retry_backoff**(k-1), retry_delay_cap),
    # scaled by a uniform +/- retry_jitter fraction (deterministic RNG) so
    # co-failing queries don't re-dispatch in lockstep
    retry_delay: float = 0.25
    retry_backoff: float = 2.0
    retry_delay_cap: float = 2.0
    retry_jitter: float = 0.1
    max_retries: int = 8
    heartbeat_timeout: float = 6.0
    # baseline-policy switches (paper §8.1): INDV = no variant upgrading;
    # STATIC = no worker autoscaling at all (preloaded fixed replicas)
    worker_autoscale: bool = True
    allow_upgrade: bool = True


class Master:
    def __init__(self, store: MetadataStore, repo: ModelRepository,
                 loop: Clock, cfg: MasterConfig = MasterConfig(),
                 autoscale: bool = True,
                 executor_factory: Optional[Callable[[], object]] = None):
        self.store = store
        self.repo = repo
        self.loop = loop
        self.cfg = cfg
        # data-plane seam: None -> profile-driven SimExecutor per worker;
        # a factory returning worker Executors -> real engines (backend
        # "real" in sim.cluster.make_cluster)
        self.executor_factory = executor_factory
        self.selector = VariantSelector(store)
        self.workers: Dict[str, Worker] = {}
        self.metrics: List[Query] = []
        self.offline_done: List[OfflineJob] = []
        self.decision_log: List[Tuple[str, bool, float]] = []
        self._qid = itertools.count()
        self._jid = itertools.count()
        self._worker_seq = itertools.count()
        self._retry_rng = random.Random(0)   # jitter: deterministic runs
        self.autoscaler = None
        if autoscale:
            self.autoscaler = MasterAutoscaler(
                store, loop, self._start_worker_async, self._stop_worker,
                cfg.scale)
        loop.every(cfg.worker.monitor_period, self._failure_sweep)

    # ------------------------------------------------------------------
    # cluster membership (elastic scaling)
    def add_worker(self, kind: str = "accel", name: Optional[str] = None,
                   slowdown: float = 1.0) -> Worker:
        hardware = ("cpu-host", "tpu-v5e-1") if kind == "accel" \
            else ("cpu-host",)
        name = name or f"worker-{kind}-{next(self._worker_seq)}"
        executor = self.executor_factory() if self.executor_factory else None
        w = Worker(name, hardware, self.store, self.repo, self.loop,
                   self.cfg.worker, metrics=self.metrics, slowdown=slowdown,
                   executor=executor)
        if self.cfg.worker_autoscale:
            WorkerAutoscaler(w, self.store, self._request_worker_load,
                             allow_upgrade=self.cfg.allow_upgrade)
        self.workers[name] = w
        return w

    def _start_worker_async(self, kind: str, done: Callable) -> None:
        hw = HW.HARDWARE["tpu-v5e-1" if kind == "accel" else "cpu-host"]

        def boot():
            self.add_worker(kind)
            done()
        self.loop.schedule(hw.startup_latency, boot)

    def _stop_worker(self, name: str) -> None:
        w = self.workers.pop(name, None)
        if w is not None:
            w.alive = False
            self.store.mark_dead(name)

    def fail_worker(self, name: str) -> None:
        """Failure injection entry point (tests/benchmarks)."""
        w = self.workers.get(name)
        if w is not None:
            w.fail()

    def _failure_sweep(self) -> None:
        """Detect dead workers via missed heartbeats; re-route their load.

        Routing goes through ``Worker.fail()`` — the same path explicit
        failure injection uses — so the timed-out worker's pending *and
        in-flight* queries fail through their ``done_cb`` and re-enter the
        master's retry machinery, instead of stranding forever on a
        machine that will never answer (a hung worker's scheduled
        completions never fire)."""
        now = self.loop.now()
        for name, st in list(self.store.workers.items()):
            if st.alive and now - st.heartbeat > self.cfg.heartbeat_timeout:
                self.store.mark_dead(name)
                w = self.workers.get(name)
                if w is not None:
                    w.fail()

    # ------------------------------------------------------------------
    # registration (paper §3.1)
    def register_model(self, cfg: ArchConfig, submitter: str = "public",
                       is_private: bool = False,
                       accuracy: Optional[float] = None) -> int:
        task, dataset, acc = prof.ARCH_META.get(
            cfg.name, ("text-generation", "openwebtext", 0.6))
        # "verify the accuracy of a public model" — the submitted accuracy
        # must match the profiler's validation run within tolerance.
        if accuracy is not None and abs(accuracy - acc) > 0.05:
            raise ValueError(
                f"accuracy verification failed for {cfg.name}: "
                f"submitted {accuracy}, validated {acc}")
        self.store.registry.add_arch(ModelArchInfo(
            name=cfg.name, task=task, dataset=dataset, accuracy=acc,
            submitter=submitter, is_private=is_private))
        n = 0
        for v in prof.generate_variants(cfg):
            self.store.registry.add_variant(v)
            self.repo.put_size(
                v.name, cfg.param_count() * prof.DTYPE_BYTES[
                    v.framework.split("-")[-1]])
            n += 1
        return n

    # ------------------------------------------------------------------
    # query path (paper §3.3 life cycle): one submit() for every
    # granularity and both modes; everything downstream replays the spec
    def submit(self, spec: QuerySpec) -> QueryHandle:
        if spec.mode == "offline":
            return self._submit_offline(spec)
        return self._submit_online(spec)

    def _select(self, spec: QuerySpec, batch: int,
                record: bool) -> Selection:
        """Run selection at the spec's granularity. ``record`` logs the
        decision latency (first dispatch only — redispatches and offline
        selections were never part of the §8.6 overhead account)."""
        t = spec.target
        t0 = time.perf_counter()
        if isinstance(t, VariantTarget):
            sel = self.selector.select_variant(t.name, batch)
            mode = "modvar"
        elif isinstance(t, ArchTarget):
            sel = self.selector.select_arch(t.name, batch, t.slo)
            mode = "modarch"
        else:
            sel = self.selector.select_usecase(
                t.task, t.dataset, t.min_accuracy, batch, t.slo, spec.user)
            mode = "usecase"
        if record:
            decision_us = (time.perf_counter() - t0) * 1e6
            self.decision_log.append((mode, sel.needs_load, decision_us))
        return sel

    def _query_from_spec(self, spec: QuerySpec, arrival: float,
                         hedge_of: Optional[int] = None) -> Query:
        """Materialize a Query from a spec; the flat target fields are
        copies for metrics attribution, the spec itself is authoritative."""
        t = spec.target
        return Query(
            qid=next(self._qid), kind="online", n_inputs=spec.n_inputs,
            slo=spec.slo, arrival=arrival,
            arch=t.name if isinstance(t, ArchTarget) else "",
            variant=t.name if isinstance(t, VariantTarget) else "",
            task=t.task if isinstance(t, UseCaseTarget) else "",
            dataset=t.dataset if isinstance(t, UseCaseTarget) else "",
            min_accuracy=t.min_accuracy
            if isinstance(t, UseCaseTarget) else 0.0,
            user=spec.user, spec=spec, payload=spec.payload,
            hedge_of=hedge_of)

    def _submit_online(self, spec: QuerySpec) -> QueryHandle:
        q = self._query_from_spec(spec, arrival=self.loop.now())
        handle = QueryHandle(spec, self.loop, query=q)
        q.done_cb = handle._complete
        # streaming executors forward per-segment tokens through the query
        # straight into the handle (hedged duplicates are created without
        # a sink, so only the primary copy ever streams)
        q.on_tokens = handle._push_tokens
        sel = self._select(spec, batch=spec.n_inputs, record=True)
        self._dispatch(q, sel, retries=0)
        return handle

    def _retry_delay_for(self, retries: int) -> float:
        """Backoff before retry number ``retries + 1``: exponential in the
        retries already burned, capped, with deterministic +/- jitter."""
        base = min(self.cfg.retry_delay * self.cfg.retry_backoff ** retries,
                   self.cfg.retry_delay_cap)
        jit = self.cfg.retry_jitter * (2.0 * self._retry_rng.random() - 1.0)
        return max(base * (1.0 + jit), 0.0)

    def _schedule_retry(self, q: Query, retries: int) -> None:
        self.loop.schedule(self._retry_delay_for(retries),
                           lambda: self._redispatch(q, retries + 1))

    def _dispatch(self, q: Query, sel: Selection, retries: int) -> None:
        q.attempts = retries + 1
        if sel.variant is None or sel.worker is None:
            if retries < self.cfg.max_retries:
                self._schedule_retry(q, retries)
            else:
                q.failed = True
                q.finish = self.loop.now()
                self.metrics.append(q)
                if q.done_cb:
                    q.done_cb(q)
            return
        q.variant = sel.variant.name
        worker = self.workers.get(sel.worker)
        if worker is None or not worker.alive:
            self._schedule_retry(q, retries)
            return
        if sel.needs_load and self.store.instance(
                sel.variant.name, sel.worker) is None:
            worker.load_variant(sel.variant)
            q.load_wait = sel.variant.profile.load_latency * worker.slowdown
        orig_cb = q.done_cb

        def on_done(qq: Query) -> None:
            if qq.failed and retries < self.cfg.max_retries:
                # worker died under the query (or rejected it): back off,
                # then replay the immutable spec through selection again
                qq.failed = False
                qq.done_cb = orig_cb
                self._schedule_retry(qq, retries)
                return
            if orig_cb:
                orig_cb(qq)
        q.done_cb = on_done
        worker.enqueue(q, sel.variant.name)
        if self.cfg.hedge_enabled and q.slo is not None:
            self._arm_hedge(q, sel)

    def _redispatch(self, q: Query, retries: int) -> None:
        # replay the immutable spec at its original granularity — no
        # re-derivation from sentinel fields (q.variant is overwritten as
        # a side effect of every dispatch and cannot be trusted here)
        self._dispatch(q, self._select(q.spec, batch=q.n_inputs,
                                       record=False), retries)

    # -- hedged requests (straggler mitigation, DESIGN.md §6) -------------
    def _arm_hedge(self, q: Query, sel: Selection) -> None:
        v = sel.variant
        expected = v.profile.latency(q.n_inputs) + (
            v.profile.load_latency if sel.needs_load else 0.0)
        trigger = self.cfg.hedge_factor * max(expected, 1e-3)

        def check():
            if q.finish >= 0 or q.failed or q.cancelled:
                return
            insts = [i for i in self.store.running_instances_of(v.name)
                     if i.worker != sel.worker]
            if not insts:
                return
            backup = min(insts, key=lambda i: i.qps)
            # the duplicate is derived from the original spec, so hedges
            # of use-case and variant-named queries keep task / dataset /
            # min_accuracy / user / payload, and metrics attribute them
            # to the right tenant and use case
            dup = self._query_from_spec(q.spec, arrival=q.arrival,
                                        hedge_of=q.qid)

            def first_wins(winner: Query) -> None:
                if winner.failed or winner.finish < 0:
                    return            # dead duplicate must not complete
                #                       the original with bogus state
                if q.finish >= 0:
                    return            # original already answered
                q.finish = winner.finish
                q.start = winner.start
                q.variant = winner.variant
                q.worker = winner.worker
                q.violated = winner.violated
                q.outputs = winner.outputs
                q.load_wait = winner.load_wait
                q.degraded = winner.degraded
                q.preemptions = winner.preemptions
                q.cancelled = False
                if q.done_cb:
                    q.done_cb(q)
            dup.done_cb = first_wins
            w = self.workers.get(backup.worker)
            if w is not None:
                w.enqueue(dup, v.name)
        self.loop.schedule(trigger, check)

    # ------------------------------------------------------------------
    # offline queries (paper §3.2: best-effort, no latency option) — same
    # spec/handle machinery as online, including the scheduled-retry path
    # when selection cannot place the job yet
    def _submit_offline(self, spec: QuerySpec) -> QueryHandle:
        job = OfflineJob(jid=next(self._jid), variant="",
                         total_inputs=spec.n_inputs, spec=spec,
                         payload=spec.payload, arrival=self.loop.now())
        handle = QueryHandle(spec, self.loop, job=job)

        def record(j: OfflineJob) -> None:
            j.finish = self.loop.now()
            if not j.failed:
                self.offline_done.append(j)
            handle._complete()
        job.done_cb = record
        self._dispatch_offline(job, retries=0)
        return handle

    def _dispatch_offline(self, job: OfflineJob, retries: int) -> None:
        job.attempts = retries + 1
        sel = self._select(job.spec, batch=1, record=False)
        worker = None
        if sel.variant is not None and sel.worker is not None:
            worker = self.workers.get(sel.worker)
            if worker is not None and not worker.alive:
                worker = None
        if worker is not None and sel.needs_load and self.store.instance(
                sel.variant.name, sel.worker) is None:
            if not worker.load_variant(sel.variant):
                # selection used heartbeat-stale memory accounting and the
                # device filled meanwhile: re-enter the retry loop rather
                # than parking the job on a worker that will never host
                # the variant
                worker = None
        if worker is None:
            # nothing can serve it yet: backed-off scheduled retry, like
            # online
            if retries < self.cfg.max_retries:
                self.loop.schedule(
                    self._retry_delay_for(retries),
                    lambda: self._dispatch_offline(job, retries + 1))
            else:
                job.failed = True
                if job.done_cb:
                    job.done_cb(job)
            return
        job.variant = sel.variant.name
        worker.submit_offline(job)

    # ------------------------------------------------------------------
    # deprecated kwargs forms (thin shims over QuerySpec)
    def online_query(self, *, n_inputs: int = 1, slo: Optional[float] = None,
                     arch: Optional[str] = None,
                     variant: Optional[str] = None,
                     task: Optional[str] = None, dataset: Optional[str] = None,
                     accuracy: float = 0.0, user: str = "public",
                     done_cb: Optional[Callable] = None) -> Query:
        warnings.warn("Master.online_query(**kwargs) is deprecated; use "
                      "submit(QuerySpec...)", DeprecationWarning,
                      stacklevel=2)
        spec = _spec_from_kwargs(mode="online", variant=variant, arch=arch,
                                 task=task, dataset=dataset,
                                 accuracy=accuracy, slo=slo, user=user,
                                 n_inputs=n_inputs)
        h = self.submit(spec)
        if done_cb is not None:
            h.add_done_callback(lambda hh: done_cb(hh.query))
        return h.query

    def offline_query(self, *, n_inputs: int, arch: Optional[str] = None,
                      variant: Optional[str] = None,
                      task: Optional[str] = None,
                      dataset: Optional[str] = None, accuracy: float = 0.0,
                      done_cb: Optional[Callable] = None) -> OfflineJob:
        warnings.warn("Master.offline_query(**kwargs) is deprecated; use "
                      "submit(QuerySpec(..., mode='offline'))",
                      DeprecationWarning, stacklevel=2)
        spec = _spec_from_kwargs(mode="offline", variant=variant, arch=arch,
                                 task=task, dataset=dataset,
                                 accuracy=accuracy, slo=None, user="public",
                                 n_inputs=n_inputs)
        h = self.submit(spec)
        if done_cb is not None:
            h.add_done_callback(lambda hh: done_cb(hh.job))
        return h.job

    # ------------------------------------------------------------------
    # worker-initiated placements (upgrade to hardware the worker lacks)
    def _request_worker_load(self, variant: Variant, origin: str) -> None:
        sel_worker = self.selector._worker_for_load(variant)
        if sel_worker is None:
            return
        w = self.workers.get(sel_worker)
        if w is not None and self.store.instance(
                variant.name, sel_worker) is None:
            w.load_variant(variant)
