"""Two-level autoscaling (paper §6).

Worker autoscaler (§6.2): per running variant, compare current batch-weighted
load w_curr against the servable max w_max. If the remaining delta cannot
absorb a 5% spike, scale up by (a) replication on CPU, or (b) variant
upgrading (CPU -> accelerator, or accelerator variant optimized for a larger
batch). Scale-down is hysteretic: T consecutive supportable slots (10 CPU /
20 accel) before removing a replica or downgrading; an accel batch-1 variant
downgrades to CPU.

Master autoscaler (§6.1): blacklists workers above 80% utilization or with
latency spikes, starts a new accelerator worker when accelerator models are
contended, a CPU-only worker when only CPU is saturated (threshold 65%), and
retires idle workers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

from repro.core.abstraction import Variant
from repro.core.metadata import MetadataStore
from repro.core.worker import Worker
from repro.sim import hardware as HW


# ---------------------------------------------------------------------------
# variant family navigation


def _family(store: MetadataStore, v: Variant) -> List[Variant]:
    """Variants of the same arch+hardware+framework, sorted by batch_opt."""
    out = [w for w in store.registry.variants_of(v.arch)
           if w.hardware == v.hardware and w.framework == v.framework]
    return sorted(out, key=lambda w: w.batch_opt)


def upgrade_candidate(store: MetadataStore, v: Variant) -> Optional[Variant]:
    fam = _family(store, v)
    bigger = [w for w in fam if w.batch_opt > v.batch_opt]
    return bigger[0] if bigger else None


def downgrade_candidate(store: MetadataStore, v: Variant) -> Optional[Variant]:
    fam = _family(store, v)
    smaller = [w for w in fam if w.batch_opt < v.batch_opt]
    return smaller[-1] if smaller else None


def accel_upgrade_for_load(store: MetadataStore, v: Variant,
                           load_qps: float) -> Optional[Variant]:
    """Cheapest accelerator variant of the same arch that can serve the load."""
    cands = [w for w in store.registry.variants_of(v.arch) if w.is_accel]
    cands = [w for w in cands if w.profile.peak_qps >= load_qps]
    cands.sort(key=lambda w: (HW.HARDWARE[w.hardware].cost_rate,
                              w.batch_opt))
    return cands[0] if cands else None


def cpu_downgrade(store: MetadataStore, v: Variant) -> Optional[Variant]:
    cands = [w for w in store.registry.variants_of(v.arch) if not w.is_accel]
    cands.sort(key=lambda w: -w.profile.peak_qps)
    return cands[0] if cands else None


# ---------------------------------------------------------------------------
# worker autoscaler


class WorkerAutoscaler:
    def __init__(self, worker: Worker, store: MetadataStore,
                 request_worker_load: Optional[Callable] = None,
                 allow_upgrade: bool = True):
        """``request_worker_load(variant, origin_worker)`` asks the master to
        place a variant on some worker with the right hardware (paper §6.2:
        a CPU-only worker coordinates with the master for a GPU upgrade).
        ``allow_upgrade=False`` reproduces the INDV baseline (replication
        only, no variant upgrading — paper §8.1)."""
        self.w = worker
        self.store = store
        self.request_worker_load = request_worker_load
        self.allow_upgrade = allow_upgrade
        self._down_counts: Dict[str, int] = {}
        self._idle_counts: Dict[str, int] = {}
        self.idle_unload_ticks = 45   # unload variants idle for this long
        worker.loop.every(worker.cfg.autoscale_period, self.tick,
                          stop=lambda: not worker.alive)

    # -- helpers -----------------------------------------------------------
    def _w_max(self, v: Variant, replicas: int) -> float:
        if v.is_accel:
            return v.profile.peak_qps
        return replicas * v.profile.peak_qps

    def _cpu_slots_free(self) -> int:
        dev = self.w.devices.get("cpu-host")
        if dev is None:
            return 0
        used = sum(li.replicas for li in self.w.instances.values()
                   if not li.variant.is_accel)
        return max(0, dev.slots - used)

    # -- the decision loop ---------------------------------------------------
    def tick(self) -> None:
        if not self.w.alive:
            return
        cfg = self.w.cfg
        for vname, li in list(self.w.instances.items()):
            st = self.store.instance(vname, self.w.name)
            if st is None or not li.running:
                continue
            v = li.variant
            w_curr = st.qps
            w_max = self._w_max(v, li.replicas)
            backlog = len(li.pending)
            # idle-unload: INFaaS does not persist idling models (paper §1)
            if w_curr < 1e-9 and not backlog and li.outstanding == 0:
                ic = self._idle_counts.get(vname, 0) + 1
                self._idle_counts[vname] = ic
                if ic >= self.idle_unload_ticks:
                    self.w.unload_variant(vname)
                    self._idle_counts.pop(vname, None)
                    continue
            else:
                self._idle_counts[vname] = 0
            if (w_max - w_curr) <= cfg.headroom * w_max or backlog > \
                    2 * v.profile.max_batch:
                self._scale_up(li, v, w_curr)
                self._down_counts[vname] = 0
            elif self._can_scale_down(li, v, w_curr):
                c = self._down_counts.get(vname, 0) + 1
                self._down_counts[vname] = c
                t_lim = cfg.t_down_accel if v.is_accel else cfg.t_down_cpu
                if c >= t_lim:
                    self._scale_down(li, v)
                    self._down_counts[vname] = 0
            else:
                self._down_counts[vname] = 0

    # -- scale up -------------------------------------------------------------
    def _scale_up(self, li, v: Variant, w_curr: float) -> None:
        target = w_curr * (1.0 + 2 * self.w.cfg.headroom) + 1e-9
        if not v.is_accel:
            needed = max(li.replicas + 1,
                         int(math.ceil(target / v.profile.peak_qps)))
            can_replicate = (needed - li.replicas) <= self._cpu_slots_free()
            upgrade = accel_upgrade_for_load(self.store, v, target) \
                if self.allow_upgrade else None
            # paper: compare loading latency + cost; pick cheaper feasible
            if can_replicate and (upgrade is None or self._replicate_cheaper(
                    v, needed, upgrade)):
                self.w.set_replicas(v.name, needed)
                return
            if upgrade is not None:
                self._upgrade_to(li, v, upgrade)
                return
            if can_replicate:
                self.w.set_replicas(v.name, needed)
            elif self.request_worker_load is not None:
                # no local headroom: replicate horizontally (INDV path)
                self.request_worker_load(v, self.w.name)
        else:
            up = upgrade_candidate(self.store, v) if self.allow_upgrade \
                else None
            if up is not None:
                self._upgrade_to(li, v, up)
            elif self.request_worker_load is not None:
                # already at max batch on this device: scale out
                self.request_worker_load(v, self.w.name)

    def _replicate_cheaper(self, v: Variant, replicas: int,
                           upgrade: Variant) -> bool:
        cfg = self.w.cfg
        cpu = HW.HARDWARE["cpu-host"]
        rep_cost = (replicas * cfg.cores_per_replica / cfg.cpu_cores) \
            * cpu.cost_rate
        up_cost = HW.HARDWARE[upgrade.hardware].cost_rate
        return rep_cost <= up_cost

    def _upgrade_to(self, li, old: Variant, new: Variant) -> None:
        if new.hardware in self.w.hardware:
            dev = self.w.devices[new.hardware]
            fits = dev.mem_used + new.profile.peak_memory <= \
                dev.hw.mem_capacity
            if fits:
                def switch():
                    # move backlog to the upgraded variant, retire the old
                    old_li = self.w.instances.get(old.name)
                    new_li = self.w.instances.get(new.name)
                    if old_li is None or new_li is None:
                        return
                    while old_li.pending:
                        new_li.pending.append(old_li.pending.popleft())
                    if old_li.outstanding == 0:
                        self.w.unload_variant(old.name)
                    self.w._try_dispatch(new.name)
                self.w.load_variant(new, on_ready=switch)
                return
        if self.request_worker_load is not None:
            self.request_worker_load(new, self.w.name)

    # -- scale down -----------------------------------------------------------
    def _can_scale_down(self, li, v: Variant, w_curr: float) -> bool:
        margin = 1.0 - self.w.cfg.headroom
        if not v.is_accel:
            if li.replicas <= 1:
                return False
            return w_curr <= margin * (li.replicas - 1) * v.profile.peak_qps
        down = downgrade_candidate(self.store, v)
        if down is not None:
            return w_curr <= margin * down.profile.peak_qps
        cpu = cpu_downgrade(self.store, v)
        if cpu is not None:
            return w_curr <= margin * cpu.profile.peak_qps
        return w_curr <= 0.05 * v.profile.peak_qps

    def _scale_down(self, li, v: Variant) -> None:
        if not v.is_accel:
            self.w.set_replicas(v.name, li.replicas - 1)
            return
        down = downgrade_candidate(self.store, v)
        if down is None:
            # batch-1 accelerator variant -> downgrade to CPU (paper §6.2)
            cpu = cpu_downgrade(self.store, v)
            if cpu is not None:
                self._upgrade_to(li, v, cpu)
            return
        self._upgrade_to(li, v, down)


# ---------------------------------------------------------------------------
# master autoscaler


@dataclasses.dataclass
class MasterScaleConfig:
    period: float = 2.0
    util_blacklist: float = 0.80
    util_unblacklist: float = 0.60
    util_scaleup: float = 0.65
    util_idle: float = 0.05
    min_workers: int = 1
    max_workers: int = 64
    latency_spike_factor: float = 2.0
    retire_grace: float = 90.0   # never retire a worker younger than this


class MasterAutoscaler:
    def __init__(self, store: MetadataStore, loop,
                 start_worker: Callable[[str], None],
                 stop_worker: Callable[[str], None],
                 cfg: MasterScaleConfig = MasterScaleConfig()):
        self.store = store
        self.loop = loop
        self.start_worker = start_worker
        self.stop_worker = stop_worker
        self.cfg = cfg
        self.pending_starts = 0
        self._started: Dict[str, float] = {}
        loop.every(cfg.period, self.tick)

    def n_workers(self) -> int:
        return sum(1 for w in self.store.workers.values() if w.alive) + \
            self.pending_starts

    def tick(self) -> None:
        now = self.loop.now()
        live = self.store.live_workers(now)
        if not live:
            return
        # ---- blacklist / un-blacklist (transient overload diversion).
        # Never blacklist the last non-blacklisted accelerator worker:
        # diverting requires somewhere to divert TO.
        accel_contended = False
        open_accel = [w for w in live if w.has_accel() and not w.blacklisted]
        for w in live:
            peak = max(w.util.values()) if w.util else 0.0
            spike = self._latency_spike(w.name)
            if peak > self.cfg.util_blacklist or spike:
                if w.has_accel() and len(open_accel) <= 1 \
                        and w in open_accel:
                    pass   # lone accel worker stays routable
                else:
                    w.blacklisted = True
                    if w in open_accel:
                        open_accel.remove(w)
                if spike and w.has_accel():
                    accel_contended = True
            elif w.blacklisted and peak < self.cfg.util_unblacklist:
                w.blacklisted = False
                if w.has_accel():
                    open_accel.append(w)
        # ---- scale out
        accel_workers = [w for w in live if w.has_accel()]
        accel_utils = [w.util.get(h, 0.0) for w in accel_workers
                       for h in w.hardware if h != "cpu-host"]
        cpu_utils = [w.util.get("cpu-host", 0.0) for w in live
                     if "cpu-host" in w.hardware]
        if self.n_workers() < self.cfg.max_workers:
            all_accel_hot = bool(accel_utils) and min(accel_utils) > \
                self.cfg.util_scaleup
            if accel_contended and all_accel_hot or (
                    accel_utils and all_accel_hot):
                self._start("accel")
            elif cpu_utils and (sum(cpu_utils) / len(cpu_utils)
                                > self.cfg.util_scaleup) \
                    and not accel_contended:
                self._start("cpu")
        # ---- retire idle workers (with a grace period so fresh capacity
        # is not dismantled before the load arrives)
        if len(live) > self.cfg.min_workers:
            for w in live:
                if now - self._started.setdefault(w.name, now) < \
                        self.cfg.retire_grace:
                    continue
                peak = max(w.util.values()) if w.util else 0.0
                has_instances = bool(self.store.worker_instances(w.name))
                if peak < self.cfg.util_idle and not has_instances:
                    self.stop_worker(w.name)
                    break   # at most one per tick (paper: not reckless)

    def _latency_spike(self, worker: str) -> bool:
        for inst in self.store.worker_instances(worker):
            v = self.store.variant(inst.variant)
            expected = v.profile.latency(v.batch_opt)
            if inst.avg_latency > self.cfg.latency_spike_factor * expected \
                    and inst.avg_latency > 0:
                return True
        return False

    def _start(self, kind: str) -> None:
        self.pending_starts += 1

        def started():
            self.pending_starts -= 1
        self.start_worker(kind, started)
