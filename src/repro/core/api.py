"""INFaaS user API (paper Table 1).

Thin facade over the master implementing the four calls with the three
query granularities of the model-less abstraction:

    register_model(modelBinary/cfg, ..., submitter, isPrivate)
    model_info(task, dataset, accuracy)
    online_query(inputs, modVar | modArch+latency | task+dataset+acc+latency)
    offline_query(inputPath, outputPath, modVar | modArch | use-case)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.configs.base import ArchConfig
from repro.core.master import Master
from repro.core.worker import OfflineJob, Query


class INFaaS:
    def __init__(self, master: Master):
        self.master = master

    # ------------------------------------------------------------------
    def register_model(self, model_cfg: ArchConfig, *, submitter: str,
                       is_private: bool = False,
                       accuracy: Optional[float] = None) -> Dict[str, Any]:
        n = self.master.register_model(model_cfg, submitter=submitter,
                                       is_private=is_private,
                                       accuracy=accuracy)
        return {"status": "ok", "arch": model_cfg.name, "num_variants": n}

    # ------------------------------------------------------------------
    def model_info(self, *, task: Optional[str] = None,
                   dataset: Optional[str] = None, accuracy: float = 0.0,
                   submitter: str = "public") -> List[Dict[str, Any]]:
        reg = self.master.store.registry
        out = []
        for a in reg.archs.values():
            if task and a.task != task:
                continue
            if dataset and a.dataset != dataset:
                continue
            if a.accuracy < accuracy or not a.accessible_by(submitter):
                continue
            out.append({
                "arch": a.name, "task": a.task, "dataset": a.dataset,
                "accuracy": a.accuracy,
                "variants": [
                    {"name": v.name, "hardware": v.hardware,
                     "batch": v.batch_opt,
                     "latency_b1_ms": v.profile.latency(1) * 1e3,
                     "load_ms": v.profile.load_latency * 1e3,
                     "mem_mb": v.profile.peak_memory / 2**20}
                    for v in reg.variants_of(a.name)],
            })
        return out

    # ------------------------------------------------------------------
    def online_query(self, *, submitter: str = "public", n_inputs: int = 1,
                     mod_var: Optional[str] = None,
                     mod_arch: Optional[str] = None,
                     task: Optional[str] = None,
                     dataset: Optional[str] = None,
                     accuracy: float = 0.0,
                     latency_ms: Optional[float] = None,
                     done_cb=None) -> Query:
        slo = latency_ms / 1e3 if latency_ms is not None else None
        return self.master.online_query(
            n_inputs=n_inputs, slo=slo, arch=mod_arch, variant=mod_var,
            task=task, dataset=dataset, accuracy=accuracy, user=submitter,
            done_cb=done_cb)

    def offline_query(self, *, submitter: str = "public", n_inputs: int,
                      mod_var: Optional[str] = None,
                      mod_arch: Optional[str] = None,
                      task: Optional[str] = None,
                      dataset: Optional[str] = None, accuracy: float = 0.0,
                      done_cb=None) -> OfflineJob:
        # input/output object-store paths are validated by the real system;
        # here n_inputs stands in for the staged input set.
        return self.master.offline_query(
            n_inputs=n_inputs, arch=mod_arch, variant=mod_var, task=task,
            dataset=dataset, accuracy=accuracy, done_cb=done_cb)
