"""INFaaS user API (paper Table 1): typed, payload-carrying model-less
queries.

The model-less abstraction lets a developer state *requirements* at one of
three granularities and leaves variant choice to the system (paper §3.2).
This module exposes that contract as two types:

``QuerySpec`` — an immutable description of one query: a tagged target

    QuerySpec.variant(name)                          # expert granularity
    QuerySpec.arch(name, latency_ms=...)             # arch + SLO
    QuerySpec.usecase(task, dataset,                 # fully model-less
                      min_accuracy=..., latency_ms=...)

plus ``user`` (submitter, for multi-tenant access control), ``mode``
("online" | "offline" best-effort), and an optional ``payload`` of real
inputs — token-id prompts with a ``max_new_tokens`` budget. Payload-
carrying specs served by a ``backend="real"`` cluster run their actual
prompts through the continuous-batching ``ServingEngine``; without a
payload the worker accounts ``n_inputs`` synthetic inputs (the simulator's
contract). A spec is a value: re-dispatch after a failure, hedged
duplicates, and offline retries all *replay the spec* rather than
re-deriving the granularity from sentinel fields.

``QueryHandle`` — the future returned by ``submit(spec)``:

    h = api.submit(QuerySpec.arch("llama3.2-1b", latency_ms=100))
    res = h.result(timeout=60.0)     # pumps the event loop until done
    res.outputs                      # per-input generated token ids (real)
    res.queue, res.load, res.compute # per-stage latency breakdown
    res.slo_met                      # SLO verdict (None when no SLO)

``done`` / ``add_done_callback`` give the non-blocking form; callbacks fire
in registration order, immediately if the handle already completed.

The pre-redesign kwargs forms (``online_query(mod_arch=..., ...)`` /
``offline_query(...)``) survive as thin deprecation shims over
``QuerySpec`` — they build the equivalent spec, submit it, and return the
raw ``Query`` / ``OfflineJob``, so existing call sites behave identically.

Also here: ``register_model(modelBinary/cfg, submitter, isPrivate)`` and
``model_info(task, dataset, accuracy)`` from Table 1.
"""
from __future__ import annotations

import dataclasses
import threading
import traceback
import warnings
from typing import (TYPE_CHECKING, Any, Callable, Dict, Iterator, List,
                    Optional, Sequence, Tuple, Union)

from repro.configs.base import ArchConfig
from repro.core.worker import OfflineJob, Query

if TYPE_CHECKING:                                    # no runtime cycle:
    from repro.core.master import Master             # master imports us


# ----------------------------------------------------------------------
# the tagged target: exactly one of the three granularities
@dataclasses.dataclass(frozen=True)
class VariantTarget:
    """Expert granularity: the user names the exact model-variant. ``slo``
    is not used for selection (the variant is pinned) but still yields the
    SLO verdict on the result."""
    name: str
    slo: Optional[float] = None      # seconds

    granularity = "variant"


@dataclasses.dataclass(frozen=True)
class ArchTarget:
    """Architecture granularity: the system picks the variant."""
    name: str
    slo: Optional[float] = None      # seconds

    granularity = "arch"


@dataclasses.dataclass(frozen=True)
class UseCaseTarget:
    """Fully model-less: (task, dataset, min accuracy) -> the system picks
    architecture and variant."""
    task: str
    dataset: str
    min_accuracy: float = 0.0
    slo: Optional[float] = None      # seconds

    granularity = "usecase"


Target = Union[VariantTarget, ArchTarget, UseCaseTarget]


def _slo_seconds(slo: Optional[float],
                 latency_ms: Optional[float]) -> Optional[float]:
    if slo is not None and latency_ms is not None:
        raise ValueError("give slo (seconds) or latency_ms, not both")
    if latency_ms is not None:
        return latency_ms / 1e3
    return slo


@dataclasses.dataclass(frozen=True)
class QueryPayload:
    """Real inputs for a query: token-id prompts + a decode budget.

    Stored as nested tuples so the spec stays immutable/hashable; use
    ``QueryPayload.of(...)`` to build one from lists / numpy arrays. On a
    ``backend="real"`` cluster each prompt becomes one
    ``serving.engine.Request`` and the generated token ids come back as
    ``QueryResult.outputs`` (one array per prompt, submission order). The
    engine enforces ``len(prompt) + max_new_tokens <= max_len``.
    """
    prompts: Tuple[Tuple[int, ...], ...]
    max_new_tokens: int = 4

    def __post_init__(self):
        if not self.prompts:
            raise ValueError("payload needs at least one prompt")
        if any(len(p) == 0 for p in self.prompts):
            raise ValueError("payload prompts must be non-empty")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @classmethod
    def of(cls, prompts: Sequence[Sequence[int]],
           max_new_tokens: int = 4) -> "QueryPayload":
        return cls(tuple(tuple(int(t) for t in p) for p in prompts),
                   max_new_tokens=max_new_tokens)

    def __len__(self) -> int:
        return len(self.prompts)


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One immutable query: tagged target + user + mode + optional payload.

    ``n_inputs`` is the batch the control plane accounts for; with a
    payload it must equal ``len(payload)`` (constructors derive it).
    Offline mode is best-effort and therefore rejects targets with an SLO
    (paper §3.2: offline has no latency option).
    """
    target: Target
    user: str = "public"
    mode: str = "online"             # "online" | "offline"
    n_inputs: int = 1
    payload: Optional[QueryPayload] = None

    def __post_init__(self):
        if not isinstance(self.target,
                          (VariantTarget, ArchTarget, UseCaseTarget)):
            raise TypeError(
                f"target must be one of VariantTarget | ArchTarget | "
                f"UseCaseTarget, got {type(self.target).__name__}")
        if self.mode not in ("online", "offline"):
            raise ValueError(f"mode must be online|offline, got {self.mode!r}")
        if self.mode == "offline" and self.target.slo is not None:
            raise ValueError("offline queries are best-effort: no SLO "
                             "(paper Table 1 has no offline latency option)")
        if self.n_inputs < 1:
            raise ValueError("n_inputs must be >= 1")
        if self.payload is not None and self.n_inputs != len(self.payload):
            raise ValueError(
                f"n_inputs={self.n_inputs} != len(payload)="
                f"{len(self.payload)}: one accounted input per prompt")

    # -- constructors (one per granularity) ----------------------------
    @classmethod
    def variant(cls, name: str, *, slo: Optional[float] = None,
                latency_ms: Optional[float] = None, user: str = "public",
                mode: str = "online", n_inputs: Optional[int] = None,
                payload: Optional[QueryPayload] = None) -> "QuerySpec":
        return cls(VariantTarget(name, _slo_seconds(slo, latency_ms)),
                   user=user, mode=mode,
                   n_inputs=cls._n(n_inputs, payload), payload=payload)

    @classmethod
    def arch(cls, name: str, *, slo: Optional[float] = None,
             latency_ms: Optional[float] = None, user: str = "public",
             mode: str = "online", n_inputs: Optional[int] = None,
             payload: Optional[QueryPayload] = None) -> "QuerySpec":
        return cls(ArchTarget(name, _slo_seconds(slo, latency_ms)),
                   user=user, mode=mode,
                   n_inputs=cls._n(n_inputs, payload), payload=payload)

    @classmethod
    def usecase(cls, task: str, dataset: str, *, min_accuracy: float = 0.0,
                slo: Optional[float] = None,
                latency_ms: Optional[float] = None, user: str = "public",
                mode: str = "online", n_inputs: Optional[int] = None,
                payload: Optional[QueryPayload] = None) -> "QuerySpec":
        return cls(UseCaseTarget(task, dataset, min_accuracy,
                                 _slo_seconds(slo, latency_ms)),
                   user=user, mode=mode,
                   n_inputs=cls._n(n_inputs, payload), payload=payload)

    @staticmethod
    def _n(n_inputs: Optional[int], payload: Optional[QueryPayload]) -> int:
        if n_inputs is None:
            return len(payload) if payload is not None else 1
        return n_inputs

    # -- views ----------------------------------------------------------
    @property
    def granularity(self) -> str:
        return self.target.granularity

    @property
    def slo(self) -> Optional[float]:
        return self.target.slo


@dataclasses.dataclass
class QueryResult:
    """Completed-query view handed out by ``QueryHandle.result()``."""
    ok: bool                          # finished and not failed
    failed: bool
    outputs: Optional[List[Any]]      # per-input token-id arrays (real
    #                                   backend with payload), else None
    latency: float                    # arrival -> finish, seconds
    queue: float                      # waiting for a device slot
    load: float                       # variant load time this query paid
    compute: float                    # service time on the device
    slo: Optional[float]
    slo_met: Optional[bool]           # None when the spec carried no SLO
    variant: str
    worker: str
    processed: int = 0                # offline: inputs completed
    total: int = 0                    # offline: inputs requested
    # served correctly but on borrowed time: some of the query's work was
    # preempted under KV memory pressure and recovered bit-identically
    # (outputs are unaffected; latency absorbed the replay)
    degraded: bool = False
    # dispatch attempts the master burned placing this query (1 = first
    # try; >1 = retried with exponential backoff after failures)
    attempts: int = 0


@dataclasses.dataclass(frozen=True)
class TokenChunk:
    """One streamed batch of generated tokens for a handle's query.

    ``input_idx`` names which payload prompt the tokens extend (chunks of
    one prompt arrive in emission order; concatenating their ``tokens``
    reproduces that prompt's final output exactly). ``t`` is the clock
    time the chunk was harvested (wall seconds under ``RealClock``)."""
    input_idx: int
    tokens: Tuple[int, ...]
    t: float


class QueryHandle:
    """Future for one submitted ``QuerySpec`` (online query or offline job).

    ``result(timeout=...)`` blocks until the query completes: under a
    virtual clock it pumps the cluster's event loop (so a client never
    needs to guess a ``run_until`` horizon), under ``RealClock`` it waits
    on a condition variable that the control plane notifies at completion.
    ``add_done_callback(fn)`` registers ``fn(handle)``; callbacks run in
    registration order, immediately if already done. Completion is
    idempotent — a hedged duplicate finishing after its winner cannot
    re-fire the handle.

    Streaming (real backend with ``stream`` enabled): ``on_tokens(cb)``
    fires ``cb(TokenChunk)`` as decode segments retire (already-received
    chunks are replayed at registration, so late registration never loses
    tokens), ``iter_tokens()`` yields the same chunks as a generator, and
    ``ttft`` reports time-to-first-token once the first chunk lands.
    Callbacks must not block: they run on the delivering thread under the
    handle's lock.
    """

    def __init__(self, spec: QuerySpec, loop,
                 query: Optional[Query] = None,
                 job: Optional[OfflineJob] = None):
        self.spec = spec
        self.query = query
        self.job = job
        self._loop = loop
        self._done = False
        self._snapshot: Optional[QueryResult] = None
        self._callbacks: List[Callable[["QueryHandle"], None]] = []
        # streaming state: chunks in emission order + registered sinks,
        # all guarded by one condition variable (reentrant so delivery
        # under the lock tolerates a cb registering another cb)
        self._cv = threading.Condition(threading.RLock())
        self._chunks: List[TokenChunk] = []
        self._token_cbs: List[Callable[[TokenChunk], None]] = []

    # -- completion machinery (driven by the master) --------------------
    def _complete(self, *_ignored) -> None:
        if self._done:
            return
        # snapshot now: a losing hedge copy finishing later mutates the
        # raw Query's finish/violated fields, and result() must keep
        # reporting the winner's latency and verdict
        self._snapshot = self._build_result()
        with self._cv:
            self._done = True
            self._cv.notify_all()
        for cb in self._callbacks:
            cb(self)
        self._callbacks.clear()

    def _push_tokens(self, input_idx: int, tokens, t: float) -> None:
        """Streaming sink the worker drives (via ``Query.on_tokens``):
        record the chunk, wake blocked iterators, fan out to callbacks."""
        chunk = TokenChunk(int(input_idx),
                           tuple(int(x) for x in tokens), float(t))
        with self._cv:
            self._chunks.append(chunk)
            self._cv.notify_all()
            for cb in list(self._token_cbs):
                try:
                    cb(chunk)
                except Exception:  # noqa: BLE001 - a broken subscriber
                    traceback.print_exc()   # must not fail the query

    # -- future surface --------------------------------------------------
    @property
    def done(self) -> bool:
        return self._done

    def add_done_callback(self,
                          fn: Callable[["QueryHandle"], None]) -> None:
        if self._done:
            fn(self)
        else:
            self._callbacks.append(fn)

    def result(self, timeout: Optional[float] = None) -> QueryResult:
        """Block until done: pump the event loop under a virtual clock
        (``timeout`` is then in virtual seconds), or wait on the handle's
        condition variable under ``RealClock`` (wall seconds)."""
        loop = self._loop
        if not getattr(loop, "virtual", True):
            with self._cv:
                if not self._cv.wait_for(lambda: self._done, timeout):
                    raise TimeoutError(
                        f"query not done after {timeout}s of wall time")
            return self._snapshot
        deadline = None if timeout is None else loop.now() + timeout
        while not self._done:
            nxt = loop.next_event_time()
            if nxt is None:
                break                     # loop drained; nothing can finish
            if deadline is not None and nxt > deadline:
                loop.run_until(deadline)
                break
            loop.step()
        if not self._done:
            raise TimeoutError(
                f"query not done after pumping the loop to "
                f"t={loop.now():.3f}s (timeout={timeout})")
        return self._snapshot

    # -- streaming surface -----------------------------------------------
    def on_tokens(self, cb: Callable[[TokenChunk], None]) -> None:
        """Register a streaming sink; chunks already received are replayed
        first (in order), then every future chunk fires ``cb`` as it
        lands. Requires the query to have been submitted with streaming
        enabled (real backend, ``stream`` on) to ever fire."""
        with self._cv:
            for chunk in self._chunks:
                cb(chunk)
            self._token_cbs.append(cb)

    def iter_tokens(self,
                    timeout: Optional[float] = None) -> Iterator[TokenChunk]:
        """Yield ``TokenChunk``s in emission order until the query
        completes. Under a virtual clock this pumps the event loop between
        chunks; under ``RealClock`` it blocks on the condition variable.
        ``timeout`` bounds the *total* iteration time."""
        loop = self._loop
        deadline = None if timeout is None else loop.now() + timeout
        i = 0
        while True:
            with self._cv:
                pending = self._chunks[i:]
                i = len(self._chunks)
                done = self._done
            for chunk in pending:
                yield chunk
            if done:
                return
            if deadline is not None and loop.now() >= deadline:
                raise TimeoutError(
                    f"query still streaming after timeout={timeout}s")
            if getattr(loop, "virtual", True):
                if not loop.step():
                    return             # loop drained; nothing can finish
            else:
                with self._cv:
                    self._cv.wait_for(
                        lambda: self._done or len(self._chunks) > i,
                        timeout=None if deadline is None
                        else max(deadline - loop.now(), 0.0))

    @property
    def chunks(self) -> List[TokenChunk]:
        """Chunks received so far (emission order), without blocking."""
        with self._cv:
            return list(self._chunks)

    @property
    def ttft(self) -> Optional[float]:
        """Time-to-first-token in clock seconds (first streamed chunk's
        harvest time minus arrival); None until the first chunk lands or
        when the query never streamed."""
        q = self.query
        if q is None or q.first_token < 0.0:
            return None
        return q.first_token - q.arrival

    # -- completed-state views -------------------------------------------
    def _build_result(self) -> QueryResult:
        if self.job is not None:
            j = self.job
            return QueryResult(
                ok=j.done and not j.failed, failed=j.failed,
                outputs=j.outputs or None,
                latency=(j.finish - j.arrival) if j.finish >= 0 else -1.0,
                queue=0.0, load=0.0, compute=0.0,
                slo=None, slo_met=None, variant=j.variant, worker="",
                processed=j.processed, total=j.total_inputs,
                degraded=j.degraded, attempts=j.attempts)
        q = self.query
        queue, load, compute = self.breakdown
        return QueryResult(
            ok=q.finish >= 0 and not q.failed, failed=q.failed,
            outputs=q.outputs, latency=q.latency,
            queue=queue, load=load, compute=compute,
            slo=q.slo, slo_met=self.slo_met,
            variant=q.variant, worker=q.worker,
            degraded=q.degraded, attempts=q.attempts)

    @property
    def breakdown(self) -> Tuple[float, float, float]:
        """(queue, load, compute) seconds; queue+load+compute == latency."""
        q = self.query
        if q is None or q.finish < 0 or q.start < 0:
            return (0.0, 0.0, 0.0)
        compute = q.finish - q.start
        load = min(q.load_wait, q.start - q.arrival)
        queue = max(q.start - q.arrival - load, 0.0)
        return (queue, load, compute)

    @property
    def slo_met(self) -> Optional[bool]:
        """SLO verdict: None when the spec carried no SLO or the query is
        not done, else whether latency stayed within it."""
        q = self.query
        if q is None or q.slo is None or q.finish < 0:
            return None
        return not q.violated


# ----------------------------------------------------------------------
class INFaaS:
    """Table-1 facade over the master."""

    def __init__(self, master: "Master"):
        self.master = master

    # ------------------------------------------------------------------
    def register_model(self, model_cfg: ArchConfig, *, submitter: str,
                       is_private: bool = False,
                       accuracy: Optional[float] = None) -> Dict[str, Any]:
        n = self.master.register_model(model_cfg, submitter=submitter,
                                       is_private=is_private,
                                       accuracy=accuracy)
        return {"status": "ok", "arch": model_cfg.name, "num_variants": n}

    # ------------------------------------------------------------------
    def model_info(self, *, task: Optional[str] = None,
                   dataset: Optional[str] = None, accuracy: float = 0.0,
                   submitter: str = "public") -> List[Dict[str, Any]]:
        reg = self.master.store.registry
        out = []
        for a in reg.archs.values():
            if task and a.task != task:
                continue
            if dataset and a.dataset != dataset:
                continue
            if a.accuracy < accuracy or not a.accessible_by(submitter):
                continue
            out.append({
                "arch": a.name, "task": a.task, "dataset": a.dataset,
                "accuracy": a.accuracy,
                "variants": [
                    {"name": v.name, "hardware": v.hardware,
                     "batch": v.batch_opt,
                     "latency_b1_ms": v.profile.latency(1) * 1e3,
                     "load_ms": v.profile.load_latency * 1e3,
                     "mem_mb": v.profile.peak_memory / 2**20}
                    for v in reg.variants_of(a.name)],
            })
        return out

    # ------------------------------------------------------------------
    def submit(self, spec: QuerySpec) -> QueryHandle:
        """The model-less query call: one path for every granularity and
        both modes. Returns a ``QueryHandle`` future."""
        return self.master.submit(spec)

    # -- deprecated kwargs forms (thin shims over QuerySpec) -------------
    def online_query(self, *, submitter: str = "public", n_inputs: int = 1,
                     mod_var: Optional[str] = None,
                     mod_arch: Optional[str] = None,
                     task: Optional[str] = None,
                     dataset: Optional[str] = None,
                     accuracy: float = 0.0,
                     latency_ms: Optional[float] = None,
                     done_cb=None) -> Query:
        """Deprecated: build a ``QuerySpec`` and call ``submit``."""
        warnings.warn("INFaaS.online_query(**kwargs) is deprecated; "
                      "use submit(QuerySpec...)", DeprecationWarning,
                      stacklevel=2)
        spec = _spec_from_kwargs(
            mode="online", variant=mod_var, arch=mod_arch, task=task,
            dataset=dataset, accuracy=accuracy,
            slo=latency_ms / 1e3 if latency_ms is not None else None,
            user=submitter, n_inputs=n_inputs)
        h = self.master.submit(spec)
        if done_cb is not None:
            h.add_done_callback(lambda hh: done_cb(hh.query))
        return h.query

    def offline_query(self, *, submitter: str = "public", n_inputs: int,
                      mod_var: Optional[str] = None,
                      mod_arch: Optional[str] = None,
                      task: Optional[str] = None,
                      dataset: Optional[str] = None, accuracy: float = 0.0,
                      done_cb=None) -> OfflineJob:
        """Deprecated: build an offline ``QuerySpec`` and call ``submit``.
        (Input/output object-store paths are validated by the real system;
        here ``n_inputs`` stands in for the staged input set.) The legacy
        form always selected as the public user — preserved here;
        spec-built offline queries honor ``user`` for access control."""
        warnings.warn("INFaaS.offline_query(**kwargs) is deprecated; "
                      "use submit(QuerySpec(..., mode='offline'))",
                      DeprecationWarning, stacklevel=2)
        del submitter                 # legacy behavior: never forwarded
        spec = _spec_from_kwargs(
            mode="offline", variant=mod_var, arch=mod_arch, task=task,
            dataset=dataset, accuracy=accuracy, slo=None, user="public",
            n_inputs=n_inputs)
        h = self.master.submit(spec)
        if done_cb is not None:
            h.add_done_callback(lambda hh: done_cb(hh.job))
        return h.job


def _spec_from_kwargs(*, mode: str, variant: Optional[str],
                      arch: Optional[str], task: Optional[str],
                      dataset: Optional[str], accuracy: float,
                      slo: Optional[float], user: str,
                      n_inputs: int) -> QuerySpec:
    """Granularity resolution of the legacy kwargs forms (variant wins,
    then arch, else use-case) — shared by the facade and master shims."""
    if variant is not None:
        target: Target = VariantTarget(variant, slo)
    elif arch is not None:
        target = ArchTarget(arch, slo)
    else:
        target = UseCaseTarget(task or "", dataset or "", accuracy, slo)
    return QuerySpec(target, user=user, mode=mode, n_inputs=n_inputs)
