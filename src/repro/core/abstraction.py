"""The model-less abstraction (paper §3.2, Fig. 7).

Three-level registry: (task, dataset) -> model architecture -> model-variant.
A variant binds an architecture to one hardware platform, an optimization
batch size, and a numeric format; variants of the same architecture share
accuracy, and differ in latency/memory/cost.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(eq=False)
class VariantProfile:
    """Profiling output (paper §4, Fig. 8): linear latency model
    t(b) = m*b + c, load latency, and peak memory.

    Mutable on purpose: the initial fit is analytic (roofline), and real
    execution (``repro.serving.executor.EngineExecutor``) re-fits m and c
    in place as measured service times accumulate, so every holder of the
    variant — selector, autoscaler, workers — sees the calibrated model.
    ``source`` records which fit is current ("analytic" | "measured").
    ``eq=False`` keeps identity semantics (and hashability, which the
    frozen ``Variant`` holding it relies on) for this shared mutable
    object."""
    m: float                  # seconds per additional batch element
    c: float                  # seconds, intercept
    load_latency: float       # seconds to load onto the target hardware
    peak_memory: float        # bytes (weights + max activation buffers)
    max_batch: int
    peak_qps: float           # saturation throughput (queries/s, batch-weighted)
    source: str = "analytic"  # "analytic" roofline fit | "measured" refit

    def latency(self, batch: int) -> float:
        return self.m * batch + self.c


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    arch: str
    hardware: str             # key into sim.hardware.HARDWARE
    framework: str            # "jax-bf16" | "jax-int8" | "jax-f32-cpu" | ...
    batch_opt: int            # batch size this variant was compiled for
    profile: VariantProfile
    accuracy: float

    @property
    def is_accel(self) -> bool:
        return self.hardware != "cpu-host"


@dataclasses.dataclass
class ModelArchInfo:
    name: str
    task: str
    dataset: str
    accuracy: float
    submitter: str = "public"
    is_private: bool = False
    allowed_users: Tuple[str, ...] = ()
    variants: List[str] = dataclasses.field(default_factory=list)

    def accessible_by(self, user: str) -> bool:
        if not self.is_private:
            return True
        return user == self.submitter or user in self.allowed_users


class Registry:
    """Static model metadata, stored inside the metadata store."""

    def __init__(self):
        self.archs: Dict[str, ModelArchInfo] = {}
        self.variants: Dict[str, Variant] = {}

    # -- registration -----------------------------------------------------
    def add_arch(self, info: ModelArchInfo) -> None:
        self.archs[info.name] = info

    def add_variant(self, v: Variant) -> None:
        self.variants[v.name] = v
        arch = self.archs[v.arch]
        if v.name not in arch.variants:
            arch.variants.append(v.name)

    # -- the three lookup granularities ------------------------------------
    def variants_of(self, arch: str) -> List[Variant]:
        return [self.variants[n] for n in self.archs[arch].variants]

    def archs_for_usecase(self, task: str, dataset: str,
                          min_accuracy: float = 0.0,
                          user: str = "public") -> List[ModelArchInfo]:
        return [a for a in self.archs.values()
                if a.task == task and a.dataset == dataset
                and a.accuracy >= min_accuracy and a.accessible_by(user)]

    def top_variants_for_usecase(self, task: str, dataset: str,
                                 min_accuracy: float, n: int = 7,
                                 user: str = "public") -> List[Variant]:
        """Top-N variants meeting the accuracy bar (paper §5: N defaults to
        7 = avg variants/arch). Ranked by batch-1 latency, but diversified:
        the best variant per (hardware, framework) group comes first, so the
        candidate set spans hardware platforms as the paper intends."""
        cands: List[Variant] = []
        for a in self.archs_for_usecase(task, dataset, min_accuracy, user):
            cands.extend(self.variants_of(a.name))
        cands.sort(key=lambda v: v.profile.latency(1))
        seen_groups = set()
        diverse: List[Variant] = []
        rest: List[Variant] = []
        for v in cands:
            g = (v.hardware, v.framework)
            if g not in seen_groups:
                seen_groups.add(g)
                diverse.append(v)
            else:
                rest.append(v)
        return (diverse + rest)[:n]
