"""Model repository (paper §4): persistent store for model-variant binaries.

In this repo a "binary" is either (a) a byte-size record for simulated
variants (load latency derives from bytes / load bandwidth), or (b) an actual
parameter pytree persisted through ``repro.distributed.checkpoint`` for real
execution on host. Workers restore from here when a variant must be loaded —
the same code path as training checkpoint-restore (fault tolerance)."""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

from repro.sim import hardware as HW


class ModelRepository:
    def __init__(self, root: Optional[str] = None):
        self._sizes: Dict[str, float] = {}
        self._blobs: Dict[str, Any] = {}
        self.root = root
        if root:
            os.makedirs(root, exist_ok=True)

    # -- simulated binaries -------------------------------------------------
    def put_size(self, name: str, num_bytes: float) -> None:
        self._sizes[name] = float(num_bytes)

    def size(self, name: str) -> float:
        return self._sizes.get(name, 0.0)

    def load_latency(self, name: str, hardware: str) -> float:
        hw = HW.HARDWARE[hardware]
        base = 0.5 if hw.kind == "cpu" else 1.0
        return base + self.size(name) / hw.load_bw

    # -- real parameter pytrees ----------------------------------------------
    def put_params(self, name: str, params: Any) -> None:
        self._blobs[name] = params
        if self.root is not None:
            from repro.distributed import checkpoint as ckpt
            ckpt.save_pytree(os.path.join(self.root, name.replace("/", "_")),
                             params)

    def get_params(self, name: str) -> Any:
        if name in self._blobs:
            return self._blobs[name]
        if self.root is not None:
            from repro.distributed import checkpoint as ckpt
            return ckpt.load_pytree(
                os.path.join(self.root, name.replace("/", "_")))
        raise KeyError(name)

    def has(self, name: str) -> bool:
        return name in self._blobs or name in self._sizes
