"""Model profiler & optimizer (paper §4).

Two profiling paths:

* **Analytic (roofline)** — used for TPU variants that cannot be executed in
  this CPU container: per-variant latency at batches {1,4,8} is derived from
  the arch's FLOPs/bytes on the target hardware spec, then fit with the
  paper's linear model t(b) = m*b + c (Fig. 8). Load latency = weight bytes /
  load bandwidth (+ engine start), peak memory = weights + buffers.

* **Measured** — times a real jitted model on host (used by the overhead
  benchmark and the examples; calibrates the cpu-host variants).

The optimizer step mirrors the paper's TensorRT flow: for every registered
architecture it emits batch-{1,4,8,16,32,64} x {bf16, int8} accelerator
variants (int8 via the Pallas dequant-GEMM kernel) plus host-CPU variants,
subject to the target's memory capacity.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.abstraction import (ModelArchInfo, Registry, Variant,
                                    VariantProfile)
from repro.sim import hardware as HW

# serializes in-place VariantProfile mutation (see refit_profile)
_refit_lock = threading.Lock()

PROFILE_BATCHES = (1, 4, 8)
OPT_BATCHES = (1, 4, 8, 16, 32, 64)
PROFILE_CTX = 512      # context length assumed for serve-step profiling

# task/dataset/accuracy registry for the assigned architectures
ARCH_META: Dict[str, Tuple[str, str, float]] = {
    "llama3.2-1b": ("text-generation", "openwebtext", 0.62),
    "minitron-8b": ("text-generation", "openwebtext", 0.70),
    "yi-9b": ("text-generation", "openwebtext", 0.72),
    "phi3-mini-3.8b": ("text-generation", "openwebtext", 0.69),
    "zamba2-1.2b": ("text-generation", "openwebtext", 0.60),
    "moonshot-v1-16b-a3b": ("text-generation", "openwebtext", 0.74),
    "qwen3-moe-235b-a22b": ("text-generation", "openwebtext", 0.78),
    "whisper-base": ("asr", "librispeech", 0.65),
    "llama-3.2-vision-90b": ("vqa", "vqa-v2", 0.80),
    "xlstm-1.3b": ("text-generation", "openwebtext", 0.58),
}

DTYPE_BYTES = {"bf16": 2.0, "int8": 1.0, "f32": 4.0}
DTYPE_ACC_DELTA = {"bf16": 0.0, "int8": -0.004, "f32": 0.001}


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    """Analytic per-decode-step cost of an architecture."""
    n_active: int            # active params per token
    n_total: int
    kv_bytes_per_seq: float  # context-cache bytes per sequence at PROFILE_CTX
    d_model: int
    n_layers: int

    def flops(self, batch: int) -> float:
        # GEMMs (2*N_active) + attention/state reads (2 * 2 * ctx * d * L)
        attn = 4.0 * self.n_layers * PROFILE_CTX * self.d_model
        return batch * (2.0 * self.n_active + attn)

    def bytes_moved(self, batch: int, wbytes: float) -> float:
        # weights stream once per step; per-sequence cache scales with batch
        return wbytes + batch * self.kv_bytes_per_seq


def workload_model(cfg: ArchConfig) -> WorkloadModel:
    if cfg.subquadratic:
        # recurrent state instead of a KV cache
        state = cfg.n_layers * cfg.d_model * 4 * 64  # coarse state bytes
        kv = float(state)
    else:
        kv = (2.0 * cfg.n_layers * PROFILE_CTX * cfg.n_kv_heads
              * cfg.head_dim * 2.0)
    return WorkloadModel(
        n_active=cfg.active_param_count(), n_total=cfg.param_count(),
        kv_bytes_per_seq=kv, d_model=cfg.d_model, n_layers=cfg.n_layers)


def fit_linear(batches: Sequence[int],
               latencies: Sequence[float]) -> Tuple[float, float]:
    """Least-squares fit of t = m*b + c (paper Fig. 8)."""
    b = np.asarray(batches, np.float64)
    t = np.asarray(latencies, np.float64)
    A = np.stack([b, np.ones_like(b)], axis=1)
    (m, c), *_ = np.linalg.lstsq(A, t, rcond=None)
    return float(max(m, 1e-9)), float(max(c, 1e-6))


def _dispatch_overhead(hw: HW.HardwareSpec) -> float:
    return 2e-4 if hw.kind == "accel" else 5e-5


def analytic_profile(cfg: ArchConfig, hw: HW.HardwareSpec, dtype: str,
                     batch_opt: int) -> VariantProfile:
    wl = workload_model(cfg)
    wbytes = wl.n_total * DTYPE_BYTES[dtype]
    eff = 0.6 if hw.kind == "accel" else 0.35
    # profile at batches spanning the variant's own operating range
    # (1 .. batch_opt), mirroring how the optimizer profiles each TensorRT
    # engine at the batch it targets; the paper's {1,4,8} extrapolation is
    # poor past the memory->compute roofline crossover (see fig8 bench).
    batches = sorted({1, max(batch_opt // 2, 1), batch_opt})
    pts = []
    for b in batches:
        t = HW.roofline_latency(wl.flops(b), wl.bytes_moved(b, wbytes),
                                hw, eff) + _dispatch_overhead(hw)
        pts.append(t)
    if len(batches) == 1:
        batches = [1, 2]
        pts = pts + [HW.roofline_latency(
            wl.flops(2), wl.bytes_moved(2, wbytes), hw, eff)
            + _dispatch_overhead(hw)]
    m, c = fit_linear(batches, pts)
    lat_max = m * batch_opt + c
    act_bytes = (batch_opt * PROFILE_CTX * cfg.d_model * 4.0
                 + batch_opt * wl.kv_bytes_per_seq)
    load = 0.5 + wbytes / hw.load_bw if hw.kind == "cpu" \
        else 1.0 + wbytes / hw.load_bw
    return VariantProfile(
        m=m, c=c, load_latency=load,
        peak_memory=wbytes + act_bytes,
        max_batch=batch_opt,
        peak_qps=batch_opt / lat_max)


def generate_variants(cfg: ArchConfig,
                      hardware: Sequence[str] = ("cpu-host", "tpu-v5e-1",
                                                 "tpu-v5e-4")) -> List[Variant]:
    """The optimizer: emit every feasible (hardware, dtype, batch) variant."""
    task, dataset, acc = ARCH_META.get(
        cfg.name, ("text-generation", "openwebtext", 0.6))
    out: List[Variant] = []
    for hw_name in hardware:
        hw = HW.HARDWARE[hw_name]
        if hw.kind == "cpu":
            combos = [("f32", 4), ("bf16", 8)]
        else:
            combos = [("bf16", b) for b in OPT_BATCHES]
            combos += [("int8", b) for b in OPT_BATCHES]
        for dtype, batch_opt in combos:
            prof = analytic_profile(cfg, hw, dtype, batch_opt)
            if prof.peak_memory > hw.mem_capacity:
                continue   # does not fit this platform
            out.append(Variant(
                name=f"{cfg.name}/{hw_name}/{dtype}-b{batch_opt}",
                arch=cfg.name, hardware=hw_name,
                framework=f"jax-{dtype}",
                batch_opt=batch_opt, profile=prof,
                accuracy=acc + DTYPE_ACC_DELTA[dtype]))
    return out


def register_all(registry: Registry, cfgs: Sequence[ArchConfig]) -> int:
    """Register every arch + its generated variants. Returns variant count."""
    n = 0
    for cfg in cfgs:
        task, dataset, acc = ARCH_META.get(
            cfg.name, ("text-generation", "openwebtext", 0.6))
        registry.add_arch(ModelArchInfo(
            name=cfg.name, task=task, dataset=dataset, accuracy=acc))
        for v in generate_variants(cfg):
            registry.add_variant(v)
            n += 1
    return n


# ---------------------------------------------------------------------------
# measured profiling (host execution)


def refit_profile(profile: VariantProfile,
                  observations: Dict[int, Sequence[float]],
                  min_points: int = 2) -> bool:
    """Re-fit a variant's latency model from measured service times.

    ``observations`` maps batch size -> measured wall-clock service times
    (seconds). Once at least ``min_points`` distinct batch sizes have been
    observed, t(b) = m*b + c is re-fit over the per-batch means and the
    profile is updated **in place** (m, c, peak_qps, source="measured"), so
    the selector and both autoscalers immediately plan with calibrated
    numbers. Returns True when a refit happened.

    This closes the loop the ROADMAP flagged: real execution feeding the
    control plane's latency model instead of one-off manual calibration.

    Thread-safe: variants (and their profiles) are shared across every
    executor in a cluster, and under the wall-clock runtime refits arrive
    from concurrent stepper threads — the in-place (m, c, peak_qps,
    source) update is serialized under a module lock so a reader never
    sees a torn fit.
    """
    pts = {b: float(np.mean(ts)) for b, ts in observations.items() if ts}
    if len(pts) < min_points:
        return False
    batches = sorted(pts)
    m, c = fit_linear(batches, [pts[b] for b in batches])
    with _refit_lock:
        profile.m, profile.c = m, c
        profile.peak_qps = \
            profile.max_batch / profile.latency(profile.max_batch)
        profile.source = "measured"
    return True


def profile_measured(step_fn: Callable[[int], None],
                     batches: Sequence[int] = PROFILE_BATCHES,
                     repeats: int = 3) -> Tuple[float, float, List[float]]:
    """Time a real step function at several batch sizes; fit t = m*b + c.

    ``step_fn(batch)`` must block until the step completes (e.g. calls
    ``.block_until_ready()``). Returns (m, c, raw_latencies).
    """
    lats = []
    for b in batches:
        step_fn(b)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            step_fn(b)
        lats.append((time.perf_counter() - t0) / repeats)
    m, c = fit_linear(batches, lats)
    return m, c, lats
