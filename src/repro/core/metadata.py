"""Metadata store (paper §4, §7): the decision-making medium shared by the
master and workers.

Implemented as an in-process key-value store with typed views, mirroring the
paper's Redis deployment (read-mostly; one-time updates applied immediately;
utilization refreshed every ~2 s by worker monitoring daemons). Snapshots
capture the static registry; dynamic state is rebuilt from worker heartbeats
after a restore (paper §7 failure handling).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.core.abstraction import Registry, Variant


@dataclasses.dataclass
class InstanceState:
    """One model-variant running on one worker."""
    variant: str
    worker: str
    replicas: int = 1
    qps: float = 0.0               # batch-weighted request rate (EWMA)
    avg_latency: float = 0.0       # seconds (EWMA)
    running: bool = True
    loading: bool = False
    last_used: float = 0.0


@dataclasses.dataclass
class WorkerState:
    name: str
    hardware: Tuple[str, ...]          # e.g. ("cpu-host", "tpu-v5e-1")
    heartbeat: float = 0.0
    util: Dict[str, float] = dataclasses.field(default_factory=dict)
    blacklisted: bool = False
    alive: bool = True
    mem_used: Dict[str, float] = dataclasses.field(default_factory=dict)

    def has_accel(self) -> bool:
        return any(h != "cpu-host" for h in self.hardware)


class MetadataStore:
    def __init__(self):
        self.registry = Registry()
        self.workers: Dict[str, WorkerState] = {}
        # (variant, worker) -> InstanceState
        self.instances: Dict[Tuple[str, str], InstanceState] = {}
        self._snapshot_blob: Optional[str] = None

    # ------------------------------------------------------------------
    # static registry passthrough
    def variant(self, name: str) -> Variant:
        return self.registry.variants[name]

    # ------------------------------------------------------------------
    # dynamic state: workers
    def upsert_worker(self, name: str, hardware: Tuple[str, ...],
                      now: float) -> WorkerState:
        w = self.workers.get(name)
        if w is None:
            w = WorkerState(name=name, hardware=tuple(hardware),
                            heartbeat=now)
            self.workers[name] = w
        return w

    def heartbeat(self, worker: str, util: Dict[str, float],
                  mem_used: Dict[str, float], now: float) -> None:
        w = self.workers[worker]
        w.heartbeat = now
        w.util = dict(util)
        w.mem_used = dict(mem_used)

    def live_workers(self, now: float, timeout: float = 6.0) -> List[WorkerState]:
        return [w for w in self.workers.values()
                if w.alive and now - w.heartbeat <= timeout]

    def mark_dead(self, worker: str) -> None:
        w = self.workers.get(worker)
        if w is not None:
            w.alive = False
        for key, inst in list(self.instances.items()):
            if inst.worker == worker:
                del self.instances[key]

    # ------------------------------------------------------------------
    # dynamic state: instances
    def instance(self, variant: str, worker: str) -> Optional[InstanceState]:
        return self.instances.get((variant, worker))

    def set_instance(self, inst: InstanceState) -> None:
        self.instances[(inst.variant, inst.worker)] = inst

    def drop_instance(self, variant: str, worker: str) -> None:
        self.instances.pop((variant, worker), None)

    def instances_of(self, variant: str) -> List[InstanceState]:
        return [i for (v, _), i in self.instances.items() if v == variant]

    def running_instances_of(self, variant: str) -> List[InstanceState]:
        out = []
        for inst in self.instances_of(variant):
            w = self.workers.get(inst.worker)
            if inst.running and not inst.loading and w and w.alive \
                    and not w.blacklisted:
                out.append(inst)
        return out

    def is_running(self, variant: str) -> bool:
        return bool(self.running_instances_of(variant))

    def worker_instances(self, worker: str) -> List[InstanceState]:
        return [i for (_, w), i in self.instances.items() if w == worker]

    # ------------------------------------------------------------------
    # overload predicate (paper §5: QPS and latency exceed profiled values)
    def is_overloaded(self, inst: InstanceState) -> bool:
        v = self.variant(inst.variant)
        qps_cap = v.profile.peak_qps * inst.replicas
        return (inst.qps >= 0.95 * qps_cap
                or inst.avg_latency > 1.5 * v.profile.latency(v.batch_opt))

    # ------------------------------------------------------------------
    # snapshot / recovery (paper §7)
    def snapshot(self) -> str:
        blob = {
            "archs": {n: {**dataclasses.asdict(a)}
                      for n, a in self.registry.archs.items()},
            "variants": {n: dataclasses.asdict(v)
                         for n, v in self.registry.variants.items()},
        }
        self._snapshot_blob = json.dumps(blob)
        return self._snapshot_blob

    @classmethod
    def restore(cls, blob: str) -> "MetadataStore":
        from repro.core.abstraction import (ModelArchInfo, Variant,
                                            VariantProfile)
        data = json.loads(blob)
        store = cls()
        for n, a in data["archs"].items():
            a = dict(a)
            a["allowed_users"] = tuple(a.get("allowed_users", ()))
            store.registry.add_arch(ModelArchInfo(**a))
        for n, v in data["variants"].items():
            v = dict(v)
            v["profile"] = VariantProfile(**v["profile"])
            store.registry.variants[n] = Variant(**v)
        # dynamic state (workers, instances) is rebuilt from heartbeats
        return store
