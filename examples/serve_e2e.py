"""End-to-end driver: REAL JAX serving of a small model through the
continuous-batching data plane (bucketed prefill admission + fused decode
segments + slot refill), with measured-vs-profiled latency comparison.

Run:  PYTHONPATH=src python examples/serve_e2e.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.core import profiler as prof
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = ARCHS["llama3.2-1b"].reduced()
    print(f"building {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) on host...")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=8, max_len=64,
                           decode_block=16)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 12)
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 17)))
            for i in range(20)]
    engine.warmup(prompt_lens=[len(r.prompt) for r in reqs])
    t0 = time.perf_counter()
    done = engine.serve(reqs)
    wall = time.perf_counter() - t0
    n_toks = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests / {n_toks} tokens in "
          f"{wall*1e3:.1f} ms ({n_toks/wall:.0f} tok/s, "
          f"{len(done)/wall:.1f} req/s with continuous batching)")
    s = engine.stats
    print(f"  engine: {s['prefill_dispatches']} prefill + "
          f"{s['decode_dispatches']} decode dispatches for "
          f"{s['decode_steps']} decode steps; compiles: "
          f"{s['prefill_traces']} prefill buckets, "
          f"{s['decode_traces']} decode program")
    for r in done[:5]:
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} -> "
              f"tokens {[int(t) for t in r.tokens]} "
              f"(latency {r.latency*1e3:.1f} ms)")

    # profile the real step like the INFaaS profiler would — warmup means
    # the measured t(b) is pure execution, no compile time inside
    def step(batch: int) -> None:
        rs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32),
                      max_new_tokens=4) for i in range(batch)]
        engine.serve(rs)

    m, c, lats = prof.profile_measured(step, batches=(1, 4, 8))
    print(f"\nmeasured latency fit: t(b) = {m*1e3:.2f}ms * b + {c*1e3:.2f}ms"
          f"  (raw: {[f'{x*1e3:.1f}ms' for x in lats]})")


if __name__ == "__main__":
    main()
