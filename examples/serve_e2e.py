"""End-to-end driver: REAL JAX serving of a small model with batched
requests through the INFaaS data plane (prefill + decode waves, adaptive
batching), with measured-vs-profiled latency comparison.

Run:  PYTHONPATH=src python examples/serve_e2e.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.core import profiler as prof
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = ARCHS["llama3.2-1b"].reduced()
    print(f"building {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) on host...")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=8)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 12)
                                        ).astype(np.int32),
                    max_new_tokens=8)
            for i in range(20)]
    t0 = time.perf_counter()
    done = engine.serve(reqs)
    wall = time.perf_counter() - t0
    print(f"served {len(done)} requests in {wall*1e3:.1f} ms "
          f"({len(done)/wall:.1f} req/s with adaptive batching)")
    for r in done[:5]:
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} -> "
              f"tokens {list(r.tokens)} (wave latency {r.latency*1e3:.1f} ms)")

    # profile the real step like the INFaaS profiler would
    def step(batch: int) -> None:
        rs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32),
                      max_new_tokens=4) for i in range(batch)]
        engine.run_wave(rs)

    m, c, lats = prof.profile_measured(step, batches=(1, 4, 8))
    print(f"\nmeasured latency fit: t(b) = {m*1e3:.2f}ms * b + {c*1e3:.2f}ms"
          f"  (raw: {[f'{x*1e3:.1f}ms' for x in lats]})")


if __name__ == "__main__":
    main()
