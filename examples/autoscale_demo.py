"""Autoscaling walkthrough: watch INFaaS replicate, upgrade, and downgrade a
model's variants as the load swings (paper Fig. 11 in miniature).

Run:  PYTHONPATH=src python examples/autoscale_demo.py
"""
from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals

ARCH = ARCHS["llama3.2-1b"]


def snapshot(cluster, t):
    lines = []
    for wname, w in cluster.master.workers.items():
        if not w.alive:
            continue
        insts = [f"{li.variant.name.split('/', 1)[1]} x{li.replicas}"
                 for li in w.instances.values()]
        if insts:
            lines.append(f"    {wname}: {', '.join(insts)}")
    util = {h: f"{u:.2f}" for w in cluster.store.workers.values() if w.alive
            for h, u in w.util.items()}
    print(f"  t={t:5.0f}s util={util}")
    for ln in lines:
        print(ln)


def main() -> None:
    c = make_cluster(n_accel=1, n_cpu=1, archs=[ARCH], autoscale=True)
    from repro.core import profiler as prof
    from repro.sim import hardware as HW
    peak_b8 = prof.analytic_profile(ARCH, HW.HARDWARE["tpu-v5e-1"],
                                    "bf16", 8).peak_qps

    # phase 1: light load (CPU should suffice)
    print("== phase 1: light load, relaxed 500ms SLO ==")
    poisson_arrivals(c.loop, lambda t: 4.0,
                     lambda t: c.api.submit(
                         QuerySpec.arch(ARCH.name, latency_ms=500)),
                     t_end=20.0, seed=1)
    c.run_until(20.0)
    snapshot(c, 20)

    # phase 2: heavy load + strict SLO (expect upgrade to batched accel)
    print("== phase 2: heavy load, strict 50ms SLO ==")
    poisson_arrivals(c.loop, lambda t: peak_b8 * 0.45,
                     lambda t: c.api.submit(
                         QuerySpec.arch(ARCH.name, latency_ms=50)),
                     t_end=40.0, seed=2)
    c.run_until(65.0)
    snapshot(c, 65)

    # phase 3: quiet again (expect hysteretic downgrade + idle unload)
    print("== phase 3: load gone (downgrades after hysteresis) ==")
    c.run_until(180.0)
    snapshot(c, 180)

    done = [q for q in c.master.metrics if not q.failed and q.finish >= 0]
    viol = sum(q.violated for q in done)
    print(f"\nserved {len(done)} queries, SLO violations: {viol} "
          f"({viol/max(len(done),1)*100:.1f}%)")
    alive = sum(1 for w in c.store.workers.values() if w.alive)
    print(f"workers alive at end: {alive}")


if __name__ == "__main__":
    main()
