"""End-to-end training driver: train a reduced llama config for a few
hundred steps with checkpoint/restart (the train_4k substrate in miniature).

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""
import argparse
import tempfile

from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.training import data as data_lib
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = build_model(cfg)
    dcfg = data_lib.DataConfig(batch=8, seq=64, seed=0)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                       total_steps=args.steps),
                       ckpt_every=50, log_every=20)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train(model, dcfg, steps=args.steps, tcfg=tcfg,
                    ckpt_dir=ckpt_dir, log=print)
        print(f"\nfinal loss: {out['losses'][-1]:.4f} "
              f"(start {out['losses'][0]:.4f})")
        # restart from the last checkpoint to prove restore works
        out2 = train(model, dcfg, steps=args.steps, tcfg=tcfg,
                     ckpt_dir=ckpt_dir, log=lambda s: None)
        print(f"restart resumed from step {out2['resumed_from']}")


if __name__ == "__main__":
    main()
