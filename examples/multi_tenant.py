"""Multi-tenant sharing + fault tolerance demo: two tenants share workers
and models; offline work fills the slack; a worker failure is detected via
heartbeats and queries are re-dispatched.

Run:  PYTHONPATH=src python examples/multi_tenant.py
"""
from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.sim.cluster import make_cluster
from repro.sim.workload import poisson_arrivals


def main() -> None:
    c = make_cluster(n_accel=2, n_cpu=1,
                     archs=[ARCHS["llama3.2-1b"], ARCHS["yi-9b"]],
                     autoscale=True)

    # tenant A: latency-sensitive llama traffic; tenant B: accurate yi-9b
    poisson_arrivals(c.loop, lambda t: 40.0,
                     lambda t: c.api.submit(QuerySpec.arch(
                         "llama3.2-1b", latency_ms=50, user="tenantA")),
                     t_end=60.0, seed=1)
    poisson_arrivals(c.loop, lambda t: 10.0,
                     lambda t: c.api.submit(QuerySpec.usecase(
                         "text-generation", "openwebtext",
                         min_accuracy=0.71, latency_ms=200,
                         user="tenantB")),
                     t_end=60.0, seed=2)
    # tenant B also runs an offline batch job in the slack
    job = c.api.submit(QuerySpec.arch("yi-9b", mode="offline",
                                      n_inputs=400, user="tenantB")).job

    c.run_until(25.0)
    # kill a worker mid-run: heartbeats stop, master re-routes
    victim = next(iter(c.master.workers))
    print(f"t=25s: injecting failure on {victim}")
    c.master.fail_worker(victim)
    c.run_until(120.0)

    done = [q for q in c.master.metrics if q.kind == "online"]
    ok = [q for q in done if not q.failed]
    by_arch = {}
    for q in ok:
        by_arch.setdefault(q.variant.split("/")[0], []).append(q)
    print(f"\nonline queries completed: {len(ok)}/{len(done)} "
          f"(failures re-dispatched transparently)")
    for arch, qs in by_arch.items():
        viol = sum(q.violated for q in qs)
        print(f"  {arch}: {len(qs)} served, {viol} SLO violations")
    print(f"offline progress: {job.processed}/{job.total_inputs}")
    print(f"dead workers: "
          f"{[n for n, w in c.store.workers.items() if not w.alive]}")
    print(f"workers alive: "
          f"{[n for n, w in c.store.workers.items() if w.alive]}")
    # accuracy isolation: tenant B's use-case queries must have hit yi-9b
    b_queries = [q for q in ok if q.variant.startswith("yi-9b")]
    print(f"tenant-B accuracy-bound queries served by yi-9b: "
          f"{len(b_queries)}")


if __name__ == "__main__":
    main()
