"""Quickstart: register models, inspect the model-less registry, and issue
online queries at all three granularities (variant / arch / use-case).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.configs.registry import ARCHS
from repro.sim.cluster import make_cluster


def main() -> None:
    # one accelerator worker + one CPU worker, INFaaS autoscaling on
    cluster = make_cluster(n_accel=1, n_cpu=1,
                           archs=[ARCHS["llama3.2-1b"], ARCHS["yi-9b"],
                                  ARCHS["whisper-base"]])
    api = cluster.api

    print("== model_info (the model-less registry) ==")
    for info in api.model_info(task="text-generation",
                               dataset="openwebtext"):
        print(f"  {info['arch']}: accuracy={info['accuracy']:.2f}, "
              f"{len(info['variants'])} variants")
        for v in info["variants"][:3]:
            print(f"     e.g. {v['name']}  lat_b1={v['latency_b1_ms']:.2f}ms"
                  f" load={v['load_ms']:.0f}ms mem={v['mem_mb']:.0f}MB")

    print("\n== online queries ==")
    # 1. use-case granularity: task + dataset + accuracy + latency
    q1 = api.online_query(task="text-generation", dataset="openwebtext",
                          accuracy=0.60, latency_ms=50)
    # 2. arch granularity: architecture + latency
    q2 = api.online_query(mod_arch="yi-9b", latency_ms=100)
    # 3. expert granularity: exact variant
    vname = next(iter(cluster.store.registry.variants))
    q3 = api.online_query(mod_var=vname)
    cluster.run_until(30.0)
    for name, q in (("use-case", q1), ("arch", q2), ("variant", q3)):
        status = "FAILED" if q.failed else f"{q.latency*1e3:.1f} ms"
        print(f"  {name:9s} -> served by {q.variant:45s} latency={status}")

    print("\n== offline (best-effort) query ==")
    job = api.offline_query(mod_arch="llama3.2-1b", n_inputs=200)
    cluster.run_until(120.0)
    print(f"  processed {job.processed}/{job.total_inputs} inputs "
          "in slack capacity")

    print("\n== decision overheads recorded by the master ==")
    for mode, needs_load, us in cluster.master.decision_log:
        print(f"  {mode:8s} needs_load={needs_load!s:5s} {us:8.1f} us")


if __name__ == "__main__":
    main()
