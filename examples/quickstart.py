"""Quickstart: register models, inspect the model-less registry, and issue
queries at all three granularities (variant / arch / use-case) through the
typed QuerySpec/QueryHandle API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.registry import ARCHS
from repro.core.api import QuerySpec
from repro.sim.cluster import make_cluster


def main() -> None:
    # one accelerator worker + one CPU worker, INFaaS autoscaling on
    cluster = make_cluster(n_accel=1, n_cpu=1,
                           archs=[ARCHS["llama3.2-1b"], ARCHS["yi-9b"],
                                  ARCHS["whisper-base"]])
    api = cluster.api

    print("== model_info (the model-less registry) ==")
    for info in api.model_info(task="text-generation",
                               dataset="openwebtext"):
        print(f"  {info['arch']}: accuracy={info['accuracy']:.2f}, "
              f"{len(info['variants'])} variants")
        for v in info["variants"][:3]:
            print(f"     e.g. {v['name']}  lat_b1={v['latency_b1_ms']:.2f}ms"
                  f" load={v['load_ms']:.0f}ms mem={v['mem_mb']:.0f}MB")

    print("\n== online queries (QuerySpec -> QueryHandle) ==")
    # 1. use-case granularity: task + dataset + accuracy + latency
    h1 = api.submit(QuerySpec.usecase("text-generation", "openwebtext",
                                      min_accuracy=0.60, latency_ms=50))
    # 2. arch granularity: architecture + latency
    h2 = api.submit(QuerySpec.arch("yi-9b", latency_ms=100))
    # 3. expert granularity: exact variant
    vname = next(iter(cluster.store.registry.variants))
    h3 = api.submit(QuerySpec.variant(vname))
    for name, h in (("use-case", h1), ("arch", h2), ("variant", h3)):
        # result() pumps the event loop until the query completes — no
        # run_until horizon guessing, no callback nesting
        res = h.result(timeout=60.0)
        status = "FAILED" if res.failed else f"{res.latency*1e3:.1f} ms"
        verdict = {True: "SLO met", False: "SLO VIOLATED",
                   None: "no SLO"}[res.slo_met]
        print(f"  {name:9s} -> {res.variant:45s} latency={status}")
        print(f"            queue={res.queue*1e3:.1f}ms "
              f"load={res.load*1e3:.1f}ms compute={res.compute*1e3:.1f}ms "
              f"[{verdict}]")

    print("\n== offline (best-effort) query ==")
    hj = api.submit(QuerySpec.arch("llama3.2-1b", mode="offline",
                                   n_inputs=200))
    job = hj.job
    cluster.run_until(cluster.loop.now() + 120.0)
    print(f"  processed {job.processed}/{job.total_inputs} inputs "
          "in slack capacity")

    print("\n== decision overheads recorded by the master ==")
    for mode, needs_load, us in cluster.master.decision_log:
        print(f"  {mode:8s} needs_load={needs_load!s:5s} {us:8.1f} us")


if __name__ == "__main__":
    main()
